"""Map state: dynamic data-parallel fan-out with bounded concurrency.

Properties (see docs/ARCHITECTURE.md invariant 8 and docs/asl.md):

* a Map over N items equals an equivalent *static* Parallel with one branch
  per item — same ordered results, same terminal context — for random item
  lists and MaxConcurrency values;
* with injected per-item failures and full tolerance, each slot equals the
  outcome of running the Iterator standalone on that item; with the
  fail-fast default, any item failure fails the state with
  ``States.MapItemFailed``;
* the admission window holds: live children never exceed MaxConcurrency
  (asserted at 10k items x window 16, the acceptance-criteria point), and
  completed children are dropped, so live state is O(window) not O(items);
* a crash mid-Map on a 4-shard pool (some items done, some in flight, some
  unadmitted) recovers to the same terminal state and aggregated result as
  an uninterrupted run;
* delta-journal replay ≡ snapshot replay for Map runs (invariant 7).
"""

import os
import random

from repro.core import asl
from repro.core.actions import ActionRegistry
from repro.core.clock import VirtualClock
from repro.core.engine import (
    RUN_FAILED,
    RUN_SUCCEEDED,
    FlowEngine,
)
from repro.core.journal import Journal, replay
from repro.core.providers import EchoProvider, SleepProvider
from repro.core.shard_pool import EngineShardPool
from repro.testing import hypothesis_shim

given, settings, st = hypothesis_shim()


def make_engine(journal: Journal | None = None, **kwargs) -> FlowEngine:
    clock = VirtualClock()
    registry = ActionRegistry()
    registry.register(EchoProvider(clock=clock))
    registry.register(SleepProvider(clock=clock))
    return FlowEngine(registry, clock=clock, journal=journal or Journal(),
                      **kwargs)


def make_pool(path: str, shards: int = 4) -> EngineShardPool:
    clock = VirtualClock()
    registry = ActionRegistry()
    registry.register(EchoProvider(clock=clock))
    registry.register(SleepProvider(clock=clock))
    return EngineShardPool(registry, num_shards=shards, clock=clock,
                           journal_path=path)


def canon(doc):
    """Normalize per-process action ids and start timestamps.

    Action ids are random; ``started`` is the virtual time an item's sleep
    began, which legitimately differs between a window-limited Map and an
    unbounded reference (admission is *delayed*, not changed).
    """
    if isinstance(doc, dict):
        return {
            k: ("<nondet>" if k in ("action_id", "started") else canon(v))
            for k, v in doc.items()
        }
    if isinstance(doc, list):
        return [canon(v) for v in doc]
    return doc


# The iterator used by the property sweeps: sleep proportional to the item
# value, echo the index, and fail (catchably, with one retry-able shape)
# when the item is negative.
ITERATOR = {
    "StartAt": "Gate",
    "States": {
        "Gate": {
            "Type": "Choice",
            "Choices": [{"Variable": "$.item", "NumericLessThan": 0,
                         "Next": "Bad"}],
            "Default": "Work",
        },
        "Work": {"Type": "Action", "ActionUrl": "ap://sleep",
                 "Parameters": {"seconds.$": "$.item"},
                 "ResultPath": "$.slept", "Next": "Echo"},
        "Echo": {"Type": "Action", "ActionUrl": "ap://echo",
                 "Parameters": {"echo_string.$": "$.index"},
                 "ResultPath": "$.echoed", "End": True},
        "Bad": {"Type": "Fail", "Error": "ItemBad", "Cause": "negative item"},
    },
}


def map_definition(max_concurrency: int, tolerated: int = 0,
                   items_path: str = "$.xs") -> dict:
    return {
        "StartAt": "Fan",
        "States": {
            "Fan": {
                "Type": "Map",
                "ItemsPath": items_path,
                "MaxConcurrency": max_concurrency,
                "ToleratedFailureCount": tolerated,
                "Iterator": ITERATOR,
                "ResultPath": "$.results",
                "End": True,
            },
        },
    }


def static_parallel_definition(items: list) -> dict:
    """The static-enumeration equivalent: one branch per item, each branch
    first injecting the item scope a Map child would have received."""
    branches = []
    for i, item in enumerate(items):
        branches.append({
            "StartAt": "Inject",
            "States": {
                "Inject": {"Type": "Pass",
                           "Result": {"item": item, "index": i},
                           "ResultPath": "$", "Next": "Gate"},
                **ITERATOR["States"],
            },
        })
        branches[-1]["States"] = {
            "Inject": branches[-1]["States"]["Inject"],
            **{k: dict(v) for k, v in ITERATOR["States"].items()},
        }
    return {
        "StartAt": "Fan",
        "States": {
            "Fan": {"Type": "Parallel", "Branches": branches,
                    "ResultPath": "$.results", "End": True},
        },
    }


# --------------------------------------------- property: Map ≡ static Parallel

@settings(max_examples=12)
@given(st.integers(min_value=0, max_value=2**31))
def test_map_equals_static_parallel_reference(seed):
    rng = random.Random(seed)
    items = [round(rng.uniform(0.0, 5.0), 3) for _ in range(rng.randint(1, 12))]
    window = rng.choice([0, 1, 2, 3, 16])

    eng_map = make_engine()
    map_flow = asl.parse(map_definition(window))
    run_map = eng_map.start_run(map_flow, {"xs": items}, flow_id="m",
                                run_id="run-map")
    eng_map.run_to_completion(run_map.run_id)

    eng_par = make_engine()
    par_flow = asl.parse(static_parallel_definition(items))
    run_par = eng_par.start_run(par_flow, {"xs": items}, flow_id="p",
                                run_id="run-par")
    eng_par.run_to_completion(run_par.run_id)

    assert run_map.status == run_par.status == RUN_SUCCEEDED
    assert canon(run_map.context["results"]) == canon(
        run_par.context["results"]
    )
    if window:
        assert run_map.map_peak_live <= window


# ------------------------------- property: injected failures, tolerance, order

@settings(max_examples=12)
@given(st.integers(min_value=0, max_value=2**31))
def test_map_with_failures_matches_per_item_standalone_runs(seed):
    """Full tolerance: slot i equals the Iterator run standalone on item i
    (error document for failed items, final context for successes)."""
    rng = random.Random(seed)
    items = [
        round(rng.uniform(0.0, 3.0), 3) if rng.random() < 0.7 else -1.0
        for _ in range(rng.randint(1, 10))
    ]
    window = rng.choice([1, 2, 4])

    engine = make_engine()
    flow = asl.parse(map_definition(window, tolerated=len(items)))
    run = engine.start_run(flow, {"xs": items}, flow_id="m", run_id="run-map")
    engine.run_to_completion(run.run_id)
    assert run.status == RUN_SUCCEEDED
    results = run.context["results"]
    assert len(results) == len(items)

    iterator = asl.parse(ITERATOR)
    for i, item in enumerate(items):
        ref_engine = make_engine()
        ref = ref_engine.start_run(iterator, {"item": item, "index": i},
                                   flow_id="it", run_id=f"ref-{i}")
        ref_engine.run_to_completion(ref.run_id)
        if item < 0:
            assert ref.status == RUN_FAILED
            assert results[i]["MapItemFailed"]["Error"] == ref.error["Error"]
            assert results[i]["MapItemFailed"]["Cause"] == ref.error["Cause"]
        else:
            assert ref.status == RUN_SUCCEEDED
            assert canon(results[i]) == canon(ref.context)


@settings(max_examples=8)
@given(st.integers(min_value=0, max_value=2**31))
def test_map_fail_fast_by_default(seed):
    rng = random.Random(seed)
    items = [round(rng.uniform(0.0, 2.0), 3) for _ in range(rng.randint(1, 8))]
    items[rng.randrange(len(items))] = -1.0  # at least one failing item

    engine = make_engine()
    flow = asl.parse(map_definition(rng.choice([0, 1, 2])))
    run = engine.start_run(flow, {"xs": items}, flow_id="m", run_id="run-map")
    engine.run_to_completion(run.run_id)
    assert run.status == RUN_FAILED
    assert run.error["Error"] == "States.MapItemFailed"
    # fail-fast left no live children behind
    assert all(".m" not in rid for rid in engine.runs)


def test_map_tolerance_boundary():
    """Exactly ToleratedFailureCount failures still succeed; one more fails."""
    items = [-1.0, 1.0, -1.0, 0.5]
    ok = make_engine()
    flow = asl.parse(map_definition(2, tolerated=2))
    run = ok.start_run(flow, {"xs": items}, flow_id="m", run_id="r")
    ok.run_to_completion(run.run_id)
    assert run.status == RUN_SUCCEEDED
    assert [("MapItemFailed" in r) for r in run.context["results"]] == [
        True, False, True, False,
    ]

    bad = make_engine()
    flow2 = asl.parse(map_definition(2, tolerated=1))
    run2 = bad.start_run(flow2, {"xs": items}, flow_id="m", run_id="r")
    bad.run_to_completion(run2.run_id)
    assert run2.status == RUN_FAILED
    assert run2.error["Error"] == "States.MapItemFailed"


# ------------------------------------------------ the admission-window bound

def test_10k_items_window_16_never_exceeds_window():
    """Acceptance criterion: a 10,000-item Map with MaxConcurrency=16
    completes with the live child-run count never exceeding 16, and the
    engine's run table stays O(window), not O(items)."""
    definition = {
        "StartAt": "Fan",
        "States": {
            "Fan": {
                "Type": "Map",
                "ItemsPath": "$.xs",
                "MaxConcurrency": 16,
                # a pure-Pass iterator keeps the 10k sweep fast
                "Iterator": {
                    "StartAt": "P",
                    "States": {"P": {"Type": "Pass",
                                     "Result": {"ok": True},
                                     "ResultPath": "$.out", "End": True}},
                },
                "ResultPath": "$.results",
                "End": True,
            },
        },
    }
    engine = make_engine()
    flow = asl.parse(definition)
    n = 10_000
    run = engine.start_run(flow, {"xs": list(range(n))}, flow_id="m",
                           run_id="run-10k")
    # drain in slices, sampling the live-child population between events
    max_table = 0
    while run.status == "ACTIVE":
        stepped = engine.scheduler.drain(
            max_events=997, stop=lambda: run.status != "ACTIVE"
        )
        with run.lock:
            join = run.map_join
            if join is not None:
                assert join.live <= 16
        max_table = max(max_table, len(engine.runs))
        if stepped == 0:
            break
    assert run.status == RUN_SUCCEEDED
    assert run.map_peak_live <= 16
    assert len(run.context["results"]) == n
    assert run.context["results"][1234] == {"item": 1234, "index": 1234,
                                            "out": {"ok": True}}
    # live state stayed bounded: parent + at most the window of children
    assert max_table <= 1 + 16
    assert list(engine.runs) == ["run-10k"]
    assert engine.stats["map_items_completed"] == n


# ------------------------------------------- crash mid-Map on a 4-shard pool

@settings(max_examples=6)
@given(st.integers(min_value=0, max_value=2**31))
def test_crash_mid_map_recovers_to_reference(seed, tmp_path_factory=None):
    """Kill a 4-shard pool mid-Map — some items done, some in flight, some
    unadmitted — and recover: terminal state and aggregated result must
    match an uninterrupted run."""
    import tempfile

    rng = random.Random(seed)
    items = [float(rng.randint(0, 6)) for _ in range(rng.randint(6, 24))]
    window = rng.choice([2, 3, 5])
    cut = rng.uniform(0.5, 8.0)
    flow = asl.parse(map_definition(window))

    with tempfile.TemporaryDirectory(prefix="mapcrash-") as base:
        ref_pool = make_pool(os.path.join(base, "ref.jsonl"))
        ref = ref_pool.start_run(flow, {"xs": items}, flow_id="f1",
                                 run_id="run-x")
        ref_pool.run_to_completion(ref.run_id)
        assert ref.status == RUN_SUCCEEDED

        crash_pool = make_pool(os.path.join(base, "crash.jsonl"))
        crash_pool.start_run(flow, {"xs": items}, flow_id="f1",
                              run_id="run-x")
        crash_pool.scheduler.drain(until=cut)  # "crash": abandon the pool

        recovered_pool = make_pool(os.path.join(base, "crash.jsonl"))
        resumed = recovered_pool.recover({"f1": flow})
        assert [r.run_id for r in resumed] == ["run-x"]
        after = recovered_pool.run_to_completion("run-x")
        assert after.status == ref.status
        assert canon(after.context) == canon(ref.context)
        assert after.map_peak_live <= window
        # no orphaned children in the recovered pool
        assert all(".m" not in rid for rid in recovered_pool.runs)


# ----------------------- cross-shard fan-out ≡ single-shard reference

@settings(max_examples=8)
@given(st.integers(min_value=0, max_value=2**31))
def test_multishard_map_equals_single_shard_reference(seed):
    """Map children spread across the pool (``.mN`` kept in the placement
    key) must be invisible to flow semantics: same ordered results, same
    terminal context, same virtual completion time as the shards=1 run —
    including failed items under full tolerance, whose error documents
    route back to the owner's join from foreign shards."""
    rng = random.Random(seed)
    items = [
        round(rng.uniform(0.0, 5.0), 3) if rng.random() < 0.8 else -1.0
        for _ in range(rng.randint(4, 20))
    ]
    window = rng.choice([2, 4, 8])
    flow = asl.parse(map_definition(window, tolerated=len(items)))

    outcomes = {}
    spreads = {}
    for shards in (1, 4, 8):
        pool = make_pool(None, shards=shards)
        run = pool.start_run(flow, {"xs": items}, flow_id="m", run_id="run-ms")
        pool.run_to_completion(run.run_id)
        assert run.status == RUN_SUCCEEDED
        assert run.map_peak_live <= window
        # completed fan-out leaves no children and no foreign-index residue
        assert all(".m" not in rid for rid in pool.runs)
        assert pool._foreign == {}
        outcomes[shards] = (run.status, canon(run.context),
                            run.completion_time)
        spreads[shards] = [e.stats["map_items_completed"]
                           for e in pool.engines]
    assert outcomes[4] == outcomes[1]
    assert outcomes[8] == outcomes[1]
    # every item executed exactly once, and (hash spread + least-loaded
    # stealing) the pool actually distributed them
    assert sum(spreads[4]) == len(items)
    if len(items) >= 8 and window >= 2:
        assert sum(1 for hosted in spreads[4] if hosted) >= 2


def test_multishard_fail_fast_cancels_foreign_children():
    """Fail-fast must sweep in-flight siblings on *other* shards: the
    cancel is routed to each child's host engine, not the owner's."""
    items = [5.0] * 5 + [-1.0] + [5.0] * 6  # index 5 fails mid-first-wave
    flow = asl.parse(map_definition(6))
    pool = make_pool(None, shards=4)
    run = pool.start_run(flow, {"xs": items}, flow_id="m", run_id="run-ff")
    pool.run_to_completion(run.run_id)
    assert run.status == RUN_FAILED
    assert run.error["Error"] == "States.MapItemFailed"
    # no orphaned children anywhere in the pool, no foreign-index leaks
    assert all(".m" not in rid for rid in pool.runs)
    assert pool._foreign == {}


def test_skewed_item_costs_steal_across_shards():
    """Every 4th item is 100x slower: hash placement alone piles long
    sleeps onto whichever shard their ids hash to, so the least-loaded
    override must steal some children — without changing the outcome or
    the deterministic virtual timeline."""
    items = [100.0 if i % 4 == 0 else 1.0 for i in range(64)]
    flow = asl.parse(map_definition(8))

    ref_engine = make_engine()
    ref = ref_engine.start_run(flow, {"xs": items}, flow_id="m",
                               run_id="run-skew")
    ref_engine.run_to_completion(ref.run_id)
    assert ref.status == RUN_SUCCEEDED

    pool = make_pool(None, shards=4)
    run = pool.start_run(flow, {"xs": items}, flow_id="m", run_id="run-skew")
    pool.run_to_completion(run.run_id)

    assert run.status == RUN_SUCCEEDED
    assert canon(run.context) == canon(ref.context)
    assert run.completion_time == ref.completion_time
    spread = [e.stats["map_items_completed"] for e in pool.engines]
    assert sum(spread) == len(items) and all(spread)  # every shard hosted
    assert pool.stats["map_children_stolen"] > 0
    assert pool._foreign == {}  # stolen placements were forgotten on drop


def test_crash_mid_map_children_recover_from_foreign_segments(tmp_path):
    """Children journal on their *host* shard: after a mid-Map crash their
    records span several segments, and recovery must merge every shard's
    replayed terminal children so the owner's join re-attaches finished
    items instead of re-running them."""
    from repro.core.journal import segment_path

    items = [float(i % 7) for i in range(24)]
    flow = asl.parse(map_definition(5))

    ref_pool = make_pool(str(tmp_path / "ref.jsonl"))
    ref = ref_pool.start_run(flow, {"xs": items}, flow_id="f1", run_id="run-x")
    ref_pool.run_to_completion(ref.run_id)
    assert ref.status == RUN_SUCCEEDED

    path = str(tmp_path / "crash.jsonl")
    crash_pool = make_pool(path)
    crash_pool.start_run(flow, {"xs": items}, flow_id="f1", run_id="run-x")
    crash_pool.drain(until=6.0)  # some items done, some in flight, some not

    segments_with_children = set()
    finished_children = set()
    for i in range(4):
        with open(segment_path(path, i, 4)) as fh:
            for line in fh:
                if '"run-x.m' not in line:
                    continue
                segments_with_children.add(i)
                if '"type":"run_completed"' in line:
                    finished_children.add(i)
    assert len(segments_with_children) >= 2  # fan-out really crossed shards
    assert finished_children  # at least one item was durably finished

    recovered = make_pool(path)
    resumed = recovered.recover({"f1": flow})
    assert [r.run_id for r in resumed] == ["run-x"]
    # the per-shard replays were merged into ONE table shared by every
    # engine, holding the pre-crash terminal children
    merged = recovered.engines[0].recovered_map_results
    assert merged and all(rid.startswith("run-x.m") for rid in merged)
    assert all(e.recovered_map_results is merged for e in recovered.engines)

    after = recovered.run_to_completion("run-x")
    assert after.status == RUN_SUCCEEDED
    assert canon(after.context) == canon(ref.context)
    assert not merged  # every replayed terminal child was adopted (one-shot)
    assert all(".m" not in rid for rid in recovered.runs)


# --------------------------- invariant 7: delta replay ≡ snapshot replay

@settings(max_examples=8)
@given(st.integers(min_value=0, max_value=2**31))
def test_map_delta_replay_equals_full_replay(seed):
    """Map runs journal through the same delta/full encodings as linear
    flows; both must replay to identical images (invariant 7) and the live
    engines must agree on every outcome."""
    rng = random.Random(seed)
    items = [
        float(rng.randint(0, 4)) if rng.random() < 0.8 else -1.0
        for _ in range(rng.randint(1, 8))
    ]
    tolerated = rng.choice([0, len(items)])
    flow = asl.parse(map_definition(rng.choice([1, 2, 0]), tolerated))

    views = {}
    for mode, delta in (("full", False), ("delta", True)):
        journal = Journal()
        engine = make_engine(journal, delta_journal=delta, snapshot_every=4)
        run = engine.start_run(flow, {"xs": items}, flow_id="m",
                               run_id="run-map")
        engine.run_to_completion(run.run_id)
        views[mode] = (
            run.status,
            canon(run.context),
            canon(run.error),
            {
                rid: (im.status, canon(im.context))
                for rid, im in replay(journal).items()
            },
        )
    assert views["full"] == views["delta"]


# --------------------------------------------------------- smaller semantics

def test_item_selector_shapes_child_input():
    definition = {
        "StartAt": "Fan",
        "States": {
            "Fan": {
                "Type": "Map",
                "ItemsPath": "$.files",
                "ItemSelector": {"path.$": "$.item", "rank.$": "$.index",
                                 "dest.$": "$.context.dest", "mode": "copy"},
                "Iterator": {
                    "StartAt": "P",
                    "States": {"P": {"Type": "Pass", "End": True}},
                },
                "ResultPath": "$.out",
                "End": True,
            },
        },
    }
    engine = make_engine()
    run = engine.start_run(asl.parse(definition),
                           {"files": ["a.h5", "b.h5"], "dest": "/data"},
                           flow_id="m", run_id="r")
    engine.run_to_completion(run.run_id)
    assert run.status == RUN_SUCCEEDED
    assert run.context["out"] == [
        {"path": "a.h5", "rank": 0, "dest": "/data", "mode": "copy"},
        {"path": "b.h5", "rank": 1, "dest": "/data", "mode": "copy"},
    ]


def test_item_selector_context_is_effective_input_with_input_path():
    """Regression (review): ``$.context`` in ItemSelector must resolve
    against the Map state's *effective input* (InputPath-narrowed), the
    same document ItemsPath selected from — not the raw run context."""
    definition = {
        "StartAt": "Fan",
        "States": {
            "Fan": {
                "Type": "Map",
                "InputPath": "$.data",
                "ItemsPath": "$.files",
                "ItemSelector": {"path.$": "$.item", "tag.$": "$.context.tag"},
                "Iterator": {
                    "StartAt": "P",
                    "States": {"P": {"Type": "Pass", "End": True}},
                },
                "ResultPath": "$.out",
                "End": True,
            },
        },
    }
    engine = make_engine()
    run = engine.start_run(
        asl.parse(definition),
        {"data": {"files": ["a", "b"], "tag": "T"}, "unrelated": 1},
        flow_id="m", run_id="r",
    )
    engine.run_to_completion(run.run_id)
    assert run.status == RUN_SUCCEEDED
    assert run.context["out"] == [
        {"path": "a", "tag": "T"}, {"path": "b", "tag": "T"},
    ]


def test_directly_cancelled_child_counts_as_item_failure():
    """Regression (review): cancelling one in-flight Map item must not
    record its partial context as a successful slot — it counts against the
    failure tolerance like any failed item."""
    flow = asl.parse(map_definition(2))
    engine = make_engine()
    run = engine.start_run(flow, {"xs": [5.0, 5.0]}, flow_id="m", run_id="r")
    engine.scheduler.drain(until=1.0)  # both items mid-sleep
    engine.cancel_run("r.m0")
    engine.run_to_completion(run.run_id)
    assert run.status == RUN_FAILED
    assert run.error["Error"] == "States.MapItemFailed"

    # with tolerance, the slot carries an explicit cancellation marker
    tol_flow = asl.parse(map_definition(2, tolerated=1))
    engine2 = make_engine()
    run2 = engine2.start_run(tol_flow, {"xs": [5.0, 5.0]}, flow_id="m",
                             run_id="r")
    engine2.scheduler.drain(until=1.0)
    engine2.cancel_run("r.m0")
    engine2.run_to_completion(run2.run_id)
    assert run2.status == RUN_SUCCEEDED
    assert run2.context["results"][0]["MapItemFailed"]["Error"] == (
        "States.MapItemCancelled"
    )
    assert run2.context["results"][1]["echoed"]["status"] == "SUCCEEDED"


def test_empty_items_completes_with_empty_results():
    engine = make_engine()
    flow = asl.parse(map_definition(4))
    run = engine.start_run(flow, {"xs": []}, flow_id="m", run_id="r")
    engine.run_to_completion(run.run_id)
    assert run.status == RUN_SUCCEEDED
    assert run.context["results"] == []


def test_non_list_items_is_runtime_failure():
    engine = make_engine()
    flow = asl.parse(map_definition(4))
    run = engine.start_run(flow, {"xs": {"not": "a list"}}, flow_id="m",
                           run_id="r")
    engine.run_to_completion(run.run_id)
    assert run.status == RUN_FAILED
    assert run.error["Error"] == "States.Runtime"


def test_map_retry_clause_reruns_whole_state():
    """A Retry on the Map state re-enters it; stale children from the
    superseded attempt must not corrupt the new join."""
    definition = {
        "StartAt": "Fan",
        "States": {
            "Fan": {
                "Type": "Map",
                "ItemsPath": "$.xs",
                "MaxConcurrency": 2,
                "Iterator": ITERATOR,
                "Retry": [{"ErrorEquals": ["States.MapItemFailed"],
                           "IntervalSeconds": 1, "MaxAttempts": 2}],
                "Catch": [{"ErrorEquals": ["States.ALL"],
                           "ResultPath": "$.err", "Next": "Fallback"}],
                "ResultPath": "$.results",
                "Next": "Done",
            },
            "Fallback": {"Type": "Pass", "Result": {"recovered": True},
                         "ResultPath": "$.fb", "Next": "Done"},
            "Done": {"Type": "Succeed"},
        },
    }
    engine = make_engine()
    run = engine.start_run(asl.parse(definition), {"xs": [1.0, -1.0]},
                           flow_id="m", run_id="r")
    engine.run_to_completion(run.run_id)
    # -1.0 fails on every attempt: 1 + 2 retries, then Catch routes onward
    assert run.status == RUN_SUCCEEDED
    assert run.context["fb"] == {"recovered": True}
    assert run.context["err"]["Error"] == "States.MapItemFailed"
    assert engine.stats["retries"] == 2
    assert all(".m" not in rid for rid in engine.runs)


def test_publish_time_validation_errors():
    import pytest

    from repro.core.errors import FlowValidationError

    base = map_definition(2)

    bad_items = {"StartAt": "Fan", "States": {
        "Fan": {**base["States"]["Fan"], "ItemsPath": "$.xs["}}}
    with pytest.raises(FlowValidationError):
        asl.parse(bad_items)

    no_iterator = {"StartAt": "Fan", "States": {
        "Fan": {k: v for k, v in base["States"]["Fan"].items()
                if k != "Iterator"}}}
    with pytest.raises(FlowValidationError):
        asl.parse(no_iterator)

    bad_mc = {"StartAt": "Fan", "States": {
        "Fan": {**base["States"]["Fan"], "MaxConcurrency": -1}}}
    with pytest.raises(FlowValidationError):
        asl.parse(bad_mc)

    bad_selector = {"StartAt": "Fan", "States": {
        "Fan": {**base["States"]["Fan"],
                "ItemSelector": {"x.$": "not-a-path"}}}}
    with pytest.raises(FlowValidationError):
        asl.parse(bad_selector)

    bad_iterator = {"StartAt": "Fan", "States": {
        "Fan": {**base["States"]["Fan"],
                "Iterator": {"StartAt": "Nope", "States": {
                    "P": {"Type": "Pass", "End": True}}}}}}
    with pytest.raises(FlowValidationError):
        asl.parse(bad_iterator)


def test_map_status_rollup_reports_progress():
    engine = make_engine()
    flow = asl.parse(map_definition(2))
    run = engine.start_run(flow, {"xs": [1.0, 2.0, 3.0, 4.0]}, flow_id="m",
                           run_id="r")
    engine.scheduler.drain(until=1.5)
    doc = run.as_status()
    assert doc["map"]["items"] == 4
    assert doc["map"]["max_concurrency"] == 2
    assert doc["map"]["live"] <= 2
    engine.run_to_completion(run.run_id)
    assert "map" not in run.as_status()


def test_action_urls_walks_map_iterator():
    flow = asl.parse(map_definition(2))
    assert asl.action_urls(flow) == ["ap://sleep", "ap://echo"]
