"""Multi-tenant fairness: token buckets, weighted DRR admission, stride
ordering, and tenant stamping through the pool and the event fabric.

The paper's hosted services multiplex many users onto shared capacity; these
suites pin the admission layer's semantics (repro.core.admission) — per-tenant
rate/concurrency quotas, weighted deficit-round-robin release order, and the
unmetered fast path that keeps no-tenant submissions identical to the seed.
"""

import pytest

from repro.core.actions import ActionRegistry
from repro.core.admission import FairAdmission, StrideOrder, TokenBucket
from repro.core.auth import AuthService, Caller, Tenant
from repro.core.clock import VirtualClock
from repro.core.engine import RUN_SUCCEEDED
from repro.core.flows_service import FlowsService
from repro.core.providers import EchoProvider, SleepProvider
from repro.core.queues import QueueService

HORIZON = 1_000_000.0


# ------------------------------------------------------------- token bucket


def test_token_bucket_burst_and_refill():
    bucket = TokenBucket(rate_per_s=2.0, burst=4.0)
    assert all(bucket.try_take(0.0) for _ in range(4))  # burst capacity
    assert not bucket.try_take(0.0)
    assert bucket.next_available(0.0) == pytest.approx(0.5)  # 1 token / 2 per s
    assert bucket.try_take(0.5)
    assert not bucket.try_take(0.5)
    # refill caps at burst, never beyond
    assert bucket.next_available(100.0) == 100.0
    assert all(bucket.try_take(100.0) for _ in range(4))
    assert not bucket.try_take(100.0)
    with pytest.raises(ValueError):
        TokenBucket(rate_per_s=0.0)


# ------------------------------------------------------------- stride order


def test_stride_order_prioritizes_light_usage():
    stride = StrideOrder()
    # sweep 1: tenant a fires 6 triggers, b fires 1 (equal weight, tie on
    # pass: submission order wins, so a's batch leads the first sweep)
    out = stride.order([("a", 1.0)] * 6 + [("b", 1.0)], lambda kv: kv)
    assert out[0][0] == "a"
    # sweep 2: a consumed 6x the service, so b now outranks it
    out = stride.order([("a", 1.0), ("b", 1.0)], lambda kv: kv)
    assert out[0][0] == "b"


def test_stride_order_weight_discounts_usage():
    stride = StrideOrder()
    # a (weight 3) fires 3x, b (weight 1) fires 2x: a's pass advances 1/3
    # per firing so it sits at 1.0 vs b's 2.0 — still first next sweep
    stride.order([("a", 3.0)] * 3 + [("b", 1.0)] * 2, lambda kv: kv)
    out = stride.order([("a", 3.0), ("b", 1.0)], lambda kv: kv)
    assert out[0][0] == "a"


def test_stride_order_unmetered_and_ties():
    stride = StrideOrder()
    # None keys share one unmetered lane at weight 1; ties keep input order
    out = stride.order(["x", "y"], lambda item: (None, 1.0))
    assert out == ["x", "y"]
    out = stride.order(["x", "y"], lambda item: (None, 0.0))  # weight floor
    assert out == ["x", "y"]


# ------------------------------------------------------------ fair admission


class FakeScheduler:
    """Deferred inline scheduler: submit() queues, run_all() drains."""

    def __init__(self, clock):
        self.clock = clock
        self.queue = []
        self.timers = []

    def submit(self, fn):
        self.queue.append(fn)

    def call_at(self, t, fn):
        self.timers.append((t, fn))

    def run_all(self):
        while self.queue:
            self.queue.pop(0)()

    def fire_due(self):
        now = self.clock.now()
        due = [fn for t, fn in self.timers if t <= now]
        self.timers = [(t, fn) for t, fn in self.timers if t > now]
        for fn in due:
            fn()
        self.run_all()


class FakeRun:
    def __init__(self):
        self.status = "ACTIVE"
        self.completion_callbacks = []


def finish(run):
    run.status = "SUCCEEDED"
    for cb in run.completion_callbacks:
        cb(run)


def make_admission(window=None):
    clock = VirtualClock()
    sched = FakeScheduler(clock)
    return FairAdmission(clock, sched, window=window), sched, clock


def test_admit_now_gates():
    adm, sched, clock = make_admission(window=2)
    heavy = Tenant("heavy", max_concurrency=1)
    other = Tenant("other")
    run = FakeRun()
    assert adm.admit_now(heavy)
    adm.attach(heavy, run)
    assert not adm.admit_now(heavy)  # tenant at max_concurrency
    assert adm.admit_now(other)
    assert not adm.admit_now(other)  # global window full
    finish(run)  # frees both the tenant slot and a window slot
    assert adm.admit_now(heavy)
    assert adm.stats["admitted_direct"] == 3


def test_admit_now_respects_rate():
    adm, sched, clock = make_admission()
    paced = Tenant("paced", rate_per_s=1.0, burst=2.0)
    assert adm.admit_now(paced) and adm.admit_now(paced)
    assert not adm.admit_now(paced)  # burst spent
    clock.advance(1.0)
    assert adm.admit_now(paced)


def test_drr_release_order_is_weight_proportional():
    """With the window full, backlogged tenants drain 3:1 by weight."""
    adm, sched, clock = make_admission(window=4)
    filler = Tenant("filler")
    heavy = Tenant("heavy", weight=3.0)
    light = Tenant("light", weight=1.0)
    fillers = [FakeRun() for _ in range(4)]
    for run in fillers:
        assert adm.admit_now(filler)
        adm.attach(filler, run)
    order = []
    for _ in range(9):
        adm.enqueue(heavy, FakeRun(), lambda: order.append("heavy"))
    for _ in range(3):
        adm.enqueue(light, FakeRun(), lambda: order.append("light"))
    sched.run_all()
    assert order == []  # window full: everything parked
    for run in fillers:
        finish(run)
    sched.run_all()
    # each 4-slot batch serves 3 heavy + 1 light (deficit = weight per visit)
    assert order[:4] == ["heavy", "heavy", "heavy", "light"]
    assert adm.backlog("heavy") == 6 and adm.backlog("light") == 2
    assert adm.stats["queued"] == 12 and adm.stats["released"] == 4


def test_drr_serves_sub_unit_weights():
    """A weight-0.25 lane accumulates deficit over visits; never starved."""
    adm, sched, clock = make_admission(window=None)
    slow = Tenant("slow", weight=0.25, max_concurrency=None)
    order = []
    # no window: enqueue only lands in the lane via a full-window admit path,
    # so force the queue directly through enqueue + pump
    for _ in range(2):
        adm.enqueue(slow, FakeRun(), lambda: order.append("slow"))
    sched.run_all()
    assert order == ["slow", "slow"]  # deficit reaches 1.0 within 4 visits


def test_rate_limited_lane_uses_timed_pump():
    adm, sched, clock = make_admission(window=None)
    paced = Tenant("paced", rate_per_s=1.0, burst=1.0)
    order = []
    for i in range(3):
        adm.enqueue(paced, FakeRun(), lambda i=i: order.append(i))
    sched.run_all()
    assert order == [0]  # burst of 1; rest wait on refill
    assert sched.timers  # timed pump scheduled at the bucket's next refill
    clock.advance(1.0)
    sched.fire_due()
    assert order == [0, 1]
    clock.advance(1.0)
    sched.fire_due()
    assert order == [0, 1, 2]


def test_cancelled_queued_runs_are_skipped():
    adm, sched, clock = make_admission(window=1)
    tenant = Tenant("t")
    blocker = FakeRun()
    assert adm.admit_now(tenant)
    adm.attach(tenant, blocker)
    cancelled, live = FakeRun(), FakeRun()
    order = []
    adm.enqueue(tenant, cancelled, lambda: order.append("cancelled"))
    adm.enqueue(tenant, live, lambda: order.append("live"))
    cancelled.status = "CANCELLED"
    finish(blocker)
    sched.run_all()
    assert order == ["live"]
    assert adm.stats["cancelled_queued"] == 1


def test_try_rate_meters_inline_work():
    adm, sched, clock = make_admission()
    paced = Tenant("paced", rate_per_s=1.0, burst=1.0)
    assert adm.try_rate(None)  # unmetered callers always pass
    assert adm.try_rate(Tenant("free"))  # no rate quota: always pass
    assert adm.try_rate(paced)
    assert not adm.try_rate(paced)
    assert adm.stats["rate_deferred"] == 1
    clock.advance(1.0)
    assert adm.try_rate(paced)


# ----------------------------------------------------- pool / service wiring


ECHO_FLOW = {
    "StartAt": "E",
    "States": {
        "E": {"Type": "Action", "ActionUrl": "ap://echo",
              "Parameters": {"echo_string.$": "$.msg"},
              "ResultPath": "$.echoed", "End": True}
    },
}


def make_service(shards=2, admission_window=None, queues=None):
    clock = VirtualClock()
    auth = AuthService(clock=clock)
    registry = ActionRegistry()
    registry.register(EchoProvider(clock=clock, auth=auth))
    registry.register(SleepProvider(clock=clock, auth=auth))
    svc = FlowsService(registry, clock=clock, auth=auth, shards=shards,
                       admission_window=admission_window, queues=queues)
    return svc, auth, clock


def caller_for(auth, username, record, tenant_id=None):
    auth.create_identity(username)
    if tenant_id is not None:
        auth.assign_tenant(username, tenant_id)
    auth.grant_consent(username, record.scope)
    token = auth.issue_token(username, record.scope)
    return Caller(identity=auth.get_identity(username),
                  tokens={record.scope: token})


def test_runs_are_stamped_with_their_tenant():
    svc, auth, clock = make_service()
    auth.register_tenant("acme", weight=2.0)
    record = svc.publish_flow(ECHO_FLOW, owner="root",
                              starters=["all_authenticated_users"])
    caller = caller_for(auth, "alice", record, tenant_id="acme")
    run = svc.run_flow(record.flow_id, {"msg": "hi"}, caller=caller)
    assert run.tenant_id == "acme"
    assert run.caller.tenant_id == "acme"
    svc.engine.scheduler.drain(until=HORIZON)
    assert run.status == RUN_SUCCEEDED
    assert svc.engine.stats["admission_admitted_direct"] == 1


def test_unmetered_submissions_bypass_admission():
    svc, auth, clock = make_service(admission_window=1)
    record = svc.publish_flow(ECHO_FLOW, owner="root",
                              starters=["all_authenticated_users"])
    caller = caller_for(auth, "bob", record)  # no tenant
    runs = [svc.run_flow(record.flow_id, {"msg": str(i)}, caller=caller)
            for i in range(5)]
    svc.engine.scheduler.drain(until=HORIZON)
    assert all(r.status == RUN_SUCCEEDED for r in runs)
    assert all(r.tenant_id is None for r in runs)
    stats = svc.engine.stats
    assert stats["admission_admitted_direct"] == 0  # seed fast path
    assert stats["admission_queued"] == 0


def test_window_defers_and_completes_metered_runs():
    svc, auth, clock = make_service(shards=4, admission_window=2)
    auth.register_tenant("acme")
    record = svc.publish_flow(ECHO_FLOW, owner="root",
                              starters=["all_authenticated_users"])
    caller = caller_for(auth, "alice", record, tenant_id="acme")
    runs = [svc.run_flow(record.flow_id, {"msg": str(i)}, caller=caller)
            for i in range(8)]
    stats = svc.engine.stats
    assert stats["admission_admitted_direct"] == 2
    assert stats["admission_queued"] == 6
    svc.engine.scheduler.drain(until=HORIZON)
    assert all(r.status == RUN_SUCCEEDED for r in runs)
    assert svc.engine.stats["admission_released"] == 6


def test_tenant_max_concurrency_quota():
    svc, auth, clock = make_service(admission_window=None)
    auth.register_tenant("capped", max_concurrency=2)
    record = svc.publish_flow(ECHO_FLOW, owner="root",
                              starters=["all_authenticated_users"])
    caller = caller_for(auth, "alice", record, tenant_id="capped")
    runs = [svc.run_flow(record.flow_id, {"msg": str(i)}, caller=caller)
            for i in range(6)]
    stats = svc.engine.stats
    assert stats["admission_admitted_direct"] == 2  # quota caps direct entry
    assert stats["admission_queued"] == 4
    svc.engine.scheduler.drain(until=HORIZON)
    assert all(r.status == RUN_SUCCEEDED for r in runs)


def test_tenant_survives_passivation_and_restart(tmp_path):
    """tenant_id rides the journal: present on the dormant stub and on the
    run recovered by a fresh pool over the same segments."""
    path = str(tmp_path / "seg")
    sleep_flow = {
        "StartAt": "Z",
        "States": {"Z": {"Type": "Wait", "Seconds": 5000, "End": True}},
    }
    clock = VirtualClock()
    auth = AuthService(clock=clock)
    auth.register_tenant("acme")
    registry = ActionRegistry()
    registry.register(EchoProvider(clock=clock, auth=auth))
    svc = FlowsService(registry, clock=clock, auth=auth, shards=2,
                       journal_path=path, passivate_after=0.0)
    record = svc.publish_flow(sleep_flow, owner="root",
                              starters=["all_authenticated_users"],
                              flow_id="flow-tenant")
    caller = caller_for(auth, "alice", record, tenant_id="acme")
    run = svc.run_flow(record.flow_id, {}, caller=caller)
    assert run.tenant_id == "acme"
    svc.engine.scheduler.drain(until=10.0)  # parks at the Wait state
    stubs = svc.engine.dormant_stubs()
    assert stubs and stubs[0].tenant_id == "acme"
    svc.engine.shutdown()

    registry2 = ActionRegistry()
    registry2.register(EchoProvider(clock=clock, auth=auth))
    svc2 = FlowsService(registry2, clock=clock, auth=auth, shards=2,
                        journal_path=path)
    svc2.publish_flow(sleep_flow, owner="root",
                      starters=["all_authenticated_users"],
                      flow_id="flow-tenant")
    recovered = svc2.recover_runs()
    assert len(recovered) == 1
    assert recovered[0].tenant_id == "acme"
    svc2.engine.scheduler.drain(until=HORIZON)
    assert recovered[0].status == RUN_SUCCEEDED
    svc2.engine.shutdown()


# --------------------------------------------------------------- event fabric


def test_trigger_firings_are_rate_limited_per_tenant():
    """An over-rate tenant's trigger leaves messages unacked; the visibility
    timeout redelivers them at the tenant's sustainable rate."""
    clock = VirtualClock()
    auth = AuthService(clock=clock)
    registry = ActionRegistry()
    registry.register(EchoProvider(clock=clock, auth=auth))
    queues = QueueService(clock=clock)
    svc = FlowsService(registry, clock=clock, auth=auth, shards=1,
                       queues=queues)
    auth.register_tenant("paced", rate_per_s=0.5, burst=1.0)
    record = svc.publish_flow(ECHO_FLOW, owner="root",
                              starters=["all_authenticated_users"])
    caller = caller_for(auth, "alice", record, tenant_id="paced")
    caller.tenant = auth.tenant_of(caller.identity)
    q = queues.create_queue("events", visibility_timeout=1.0)
    trig = svc.create_trigger(q.queue_id, "True", record.flow_id,
                              transform={"msg": "msg"}, owner="alice")
    svc.enable_trigger(trig.trigger_id, caller=caller)
    for i in range(3):
        queues.send(q.queue_id, {"msg": f"m{i}"})
    svc.engine.scheduler.drain(until=60.0)
    trig = svc.router.get(trig.trigger_id)
    assert trig.stats["invocations"] == 3  # all delivered eventually...
    assert trig.stats["rate_deferred"] >= 1  # ...but not in one burst
    runs = [r for r in svc.engine.runs.values()]
    assert len(runs) == 3
    assert all(r.tenant_id == "paced" for r in runs)
