"""Live shard failover: heartbeats, fencing, online re-homing.

The acceptance property is *failover equivalence* (ARCHITECTURE invariant
12): under a VirtualClock and a seeded :class:`ChaosPlane`, killing one
shard of a pool mid-storm and re-homing its runs onto the survivors yields
the **same terminal state for every run** as the uninterrupted execution —
none lost, none double-executed — while a fenced zombie's late journal
appends provably raise :class:`JournalFenced`.
"""

import pytest

from repro.core import asl
from repro.core.actions import ActionRegistry
from repro.core.chaos import ChaosPlane
from repro.core.clock import VirtualClock
from repro.core.engine import RUN_ACTIVE, RUN_SUCCEEDED
from repro.core.journal import JournalFenced, SimulatedCrash
from repro.core.shard_pool import EngineShardPool, shard_index
from repro.core.providers import EchoProvider, SleepProvider
from repro.core.supervisor import ShardSupervisor

HORIZON = 20_000.0

#: every state retries injected ChaosErrors with capped, jittered backoff
RETRY = [{"ErrorEquals": ["ChaosError"], "IntervalSeconds": 1.0,
          "MaxAttempts": 6, "BackoffRate": 2.0,
          "MaxDelaySeconds": 8.0, "JitterStrategy": "FULL"}]

CHAIN = {
    "StartAt": "A",
    "States": {
        "A": {"Type": "Action", "ActionUrl": "ap://echo",
              "Parameters": {"echo_string.$": "$.msg"},
              "Retry": RETRY, "ResultPath": "$.a", "Next": "Pause"},
        "Pause": {"Type": "Action", "ActionUrl": "ap://sleep",
                  "Parameters": {"seconds": 50.0},
                  "Retry": RETRY, "ResultPath": "$.pause", "Next": "B"},
        "B": {"Type": "Action", "ActionUrl": "ap://echo",
              "Parameters": {"echo_string.$": "$.a.details.echo_string"},
              "Retry": RETRY, "ResultPath": "$.b", "End": True},
    },
}

MAP_FAN = {
    "StartAt": "Fan",
    "States": {
        "Fan": {
            "Type": "Map",
            "ItemsPath": "$.xs",
            "MaxConcurrency": 4,
            "Iterator": {
                "StartAt": "Nap",
                "States": {
                    "Nap": {"Type": "Action", "ActionUrl": "ap://sleep",
                            "Parameters": {"seconds": 20.0},
                            "Retry": RETRY, "ResultPath": "$.nap",
                            "Next": "Echo"},
                    "Echo": {"Type": "Action", "ActionUrl": "ap://echo",
                             "Parameters": {"echo_string.$": "$.index"},
                             "Retry": RETRY, "ResultPath": "$.echoed",
                             "End": True},
                },
            },
            "ResultPath": "$.results",
            "End": True,
        },
    },
}

PARK = {
    "StartAt": "Park",
    "States": {
        "Park": {"Type": "Wait", "Seconds": 7000.0, "Next": "Done"},
        "Done": {"Type": "Pass", "Result": {"ok": True},
                 "ResultPath": "$.done", "End": True},
    },
}


def make_pool(num_shards, chaos=None, journal_path=None,
              passivate_after=None, supervise=True,
              heartbeat_interval=5.0, heartbeat_timeout=20.0, flows=None):
    clock = VirtualClock()
    registry = ActionRegistry()
    registry.register(EchoProvider(clock=clock))
    registry.register(SleepProvider(clock=clock))
    if chaos is not None:
        chaos.clock = clock
        chaos.arm_providers(registry)
    pool = EngineShardPool(registry, num_shards=num_shards, clock=clock,
                           journal_path=journal_path,
                           passivate_after=passivate_after)
    supervisor = None
    if supervise:
        supervisor = ShardSupervisor(
            pool, heartbeat_interval=heartbeat_interval,
            heartbeat_timeout=heartbeat_timeout, chaos=chaos, flows=flows,
        )
        supervisor.start()
    return pool, clock, supervisor


def chaotic_plane(seed, kills=()):
    plane = ChaosPlane(seed=seed)
    plane.configure("provider.run", error_rate=0.15)
    plane.configure("provider.status", error_rate=0.05)
    for shard_id, at, mode in kills:
        plane.plan_kill(shard_id, at, mode=mode)
    return plane


def run_storm(num_shards, seed, kills=(), n_runs=16):
    """A fixed seeded workload; return (pool, supervisor, plane, runs)."""
    plane = chaotic_plane(seed, kills)
    pool, _, supervisor = make_pool(num_shards, chaos=plane)
    flow = asl.parse(CHAIN)
    runs = {}
    for i in range(n_runs):
        r = pool.start_run(flow, {"msg": f"m{i}"}, run_id=f"run-{i:04d}")
        runs[r.run_id] = r
    pool.drain(until=HORIZON)
    return pool, supervisor, plane, runs


# ------------------------------------------------- differential equivalence

@pytest.mark.parametrize("num_shards", [2, 4, 8])
def test_killed_shard_equals_uninterrupted(num_shards):
    """Kill 1 shard mid-storm: every victim run reaches the same terminal
    state as the uninterrupted reference — none lost, none double-run."""
    ref_pool, _, ref_plane, ref_runs = run_storm(num_shards, seed=7)
    assert all(r.status == RUN_SUCCEEDED for r in ref_runs.values())

    pool, supervisor, plane, runs = run_storm(
        num_shards, seed=7, kills=[(1, 10.0, "crash")]
    )
    assert supervisor.stats["failovers"] == 1
    assert 1 in pool.dead
    for rid, ref in ref_runs.items():
        got = pool.get_run(rid)
        assert got.status == ref.status == RUN_SUCCEEDED
        assert got.context["a"]["details"] == ref.context["a"]["details"]
        assert got.context["b"]["details"] == ref.context["b"]["details"]
    # totals add up: every run completed exactly once pool-wide
    assert sum(e.stats["runs_succeeded"] for e in pool.engines) == len(runs)
    # identical invoke-fault decisions were drawn (keyed hashing, not RNG
    # streams): the killed pool may legitimately *re-draw* a request id
    # when a failed dispatch is re-entered after the takeover, but never
    # draw a different decision for an id the reference saw.  (status
    # draws are keyed on poll *time*, which shifts for re-homed runs —
    # they are excluded by construction.)
    invokes = lambda p: {t for t in p.timeline if t[0] == "provider.run"}
    assert invokes(plane) >= invokes(ref_plane)


def test_same_seed_same_faults_across_shard_counts():
    """The chaos timeline is a function of the seed and the workload's
    request ids — not of shard count or interleaving."""
    timelines = {}
    for n in (1, 4, 8):
        _, _, plane, runs = run_storm(n, seed=21)
        assert all(r.status == RUN_SUCCEEDED for r in runs.values())
        timelines[n] = set(plane.timeline)
    assert timelines[1] == timelines[4] == timelines[8]
    assert timelines[1]  # the storm did inject faults


def test_map_fanout_killed_equals_uninterrupted():
    flow = asl.parse(MAP_FAN)
    xs = list(range(12))

    def fan(kills):
        plane = chaotic_plane(3, kills)
        pool, _, supervisor = make_pool(4, chaos=plane)
        run = pool.start_run(flow, {"xs": xs}, run_id="run-fan")
        pool.drain(until=HORIZON)
        return pool, supervisor, run

    _, _, ref = fan([])
    assert ref.status == RUN_SUCCEEDED

    pool, supervisor, got = fan([(1, 25.0, "crash")])
    assert supervisor.stats["failovers"] == 1
    assert got.status == RUN_SUCCEEDED
    assert len(got.context["results"]) == len(xs)
    for i, (g, r) in enumerate(zip(got.context["results"],
                                   ref.context["results"])):
        assert g["echoed"]["details"] == r["echoed"]["details"], i


# ----------------------------------------------------------------- fencing

def test_zombie_appends_rejected_after_fencing(tmp_path):
    path = str(tmp_path / "journal.jsonl")
    pool, _, supervisor = make_pool(4, journal_path=path)
    flow = asl.parse(CHAIN)
    runs = [pool.start_run(flow, {"msg": str(i)}, run_id=f"run-{i:04d}")
            for i in range(8)]
    pool.drain(until=10.0)  # everyone parked in Pause

    zombie_journal = pool.engines[2].journal
    supervisor.fail_shard(2, reason="test")
    # the zombie's handle is fenced: its late appends provably raise,
    # they are never silently interleaved into the segment
    with pytest.raises(JournalFenced):
        zombie_journal.append({"type": "noise", "run_id": "run-0000", "t": 0})
    # the successor epoch is journaled and strictly newer
    assert supervisor.timeline[0]["epoch"] == zombie_journal.epoch + 1
    pool.drain(until=HORIZON)
    assert all(r.status == RUN_SUCCEEDED for r in runs)


def test_fail_shard_idempotent_and_refuses_last_survivor():
    pool, _, supervisor = make_pool(2)
    flow = asl.parse(CHAIN)
    runs = [pool.start_run(flow, {"msg": str(i)}) for i in range(6)]
    pool.drain(until=10.0)
    supervisor.fail_shard(1, reason="first")
    supervisor.fail_shard(1, reason="again")  # no-op
    assert supervisor.stats["failovers"] == 1
    with pytest.raises(RuntimeError):
        supervisor.fail_shard(0, reason="nowhere to go")
    pool.drain(until=HORIZON)
    assert all(r.status == RUN_SUCCEEDED for r in runs)


# ----------------------------------------------------- detection channels

def test_hang_detected_by_heartbeat_sweep():
    """A hung shard reports nothing — only its missed beacons betray it."""
    plane = ChaosPlane(seed=1)
    plane.plan_kill(1, 5.0, mode="hang")
    pool, _, supervisor = make_pool(
        4, chaos=plane, heartbeat_interval=0.5, heartbeat_timeout=2.0
    )
    flow = asl.parse(CHAIN)
    runs = [pool.start_run(flow, {"msg": str(i)}) for i in range(12)]
    pool.drain(until=1000.0)
    assert supervisor.stats["failovers"] == 1
    event = supervisor.timeline[0]
    assert event["shard"] == 1
    assert "heartbeat silent" in event["reason"]
    # detection lag is bounded by timeout + one sweep interval
    assert 5.0 < event["detected_at"] <= 5.0 + 2.0 + 2 * 0.5
    assert all(r.status == RUN_SUCCEEDED for r in runs)


def test_worker_crash_reported_through_channel():
    """An unhandled SimulatedCrash in a shard's worker loop short-circuits
    detection: the crash channel fails the shard immediately."""
    pool, _, supervisor = make_pool(4)
    flow = asl.parse(CHAIN)
    runs = [pool.start_run(flow, {"msg": str(i)}) for i in range(12)]
    pool.drain(until=5.0)

    def boom():
        raise SimulatedCrash("injected worker crash")

    pool.engines[3].scheduler.submit(boom)
    pool.drain(until=HORIZON)
    assert supervisor.stats["failovers"] == 1
    assert supervisor.timeline[0]["shard"] == 3
    assert "worker crash" in supervisor.timeline[0]["reason"]
    assert all(r.status == RUN_SUCCEEDED for r in runs)


# ------------------------------------------------------------- re-homing

def test_dormant_stubs_repark_on_survivors():
    pool, _, supervisor = make_pool(
        2, passivate_after=0.0, heartbeat_interval=50.0,
        heartbeat_timeout=200.0,
    )
    flow = asl.parse(PARK)
    runs = [pool.start_run(flow, {}, flow_id="f", run_id=f"run-{i:04d}")
            for i in range(8)]
    pool.drain(until=10.0)
    parked_on_1 = [r.run_id for r in runs
                   if r.run_id in pool.engines[1].dormant]
    assert parked_on_1  # the victim does hold stubs

    supervisor.fail_shard(1, reason="test")
    assert supervisor.stats["stubs_reparked"] == len(parked_on_1)
    for rid in parked_on_1:
        assert rid in pool.engines[0].dormant
    pool.drain(until=HORIZON)
    for r in runs:
        done = pool.get_run(r.run_id)
        assert done.status == RUN_SUCCEEDED
        assert done.context["done"] == {"ok": True}


def test_torn_run_completed_on_host():
    """The victim died inside _complete_run: terminal in memory, not yet
    journaled.  The host journals the decision and finishes the protocol."""
    pool, _, supervisor = make_pool(2)
    flow = asl.parse(CHAIN)
    runs = [pool.start_run(flow, {"msg": str(i)}, run_id=f"run-{i:04d}")
            for i in range(8)]
    pool.drain(until=10.0)
    victim_runs = [r for r in runs if shard_index(r.run_id, 2) == 1]
    torn = victim_runs[0]
    with torn.lock:
        torn.status = RUN_SUCCEEDED  # mutated, never journaled, done unset
        torn.current_state = None
    assert not torn.done.is_set()

    supervisor.fail_shard(1, reason="test")
    assert supervisor.stats["torn_completed"] == 1
    assert torn.done.is_set()
    assert pool.get_run(torn.run_id) is torn
    pool.drain(until=HORIZON)
    for r in runs:
        assert r.status == RUN_SUCCEEDED


def test_rehoming_is_durable_for_cold_recovery(tmp_path):
    """Cold restart *mid-flight after* a live failover: every run — the
    re-homed ones included — is found exactly once, on its new segment
    (the ``run_rehomed_out`` tombstone keeps the fenced segment from
    resurrecting its copy), and completes."""
    path = str(tmp_path / "journal.jsonl")
    flow = asl.parse(CHAIN)
    pool1, _, supervisor = make_pool(4, journal_path=path)
    runs = [pool1.start_run(flow, {"msg": f"m{i}"}, run_id=f"run-{i:04d}")
            for i in range(12)]
    pool1.drain(until=10.0)
    supervisor.fail_shard(1, reason="test")
    pool1.drain(until=30.0)  # takeover done, everyone still mid-Pause
    assert all(r.status == RUN_ACTIVE for r in runs)

    pool2, _, _ = make_pool(4, journal_path=path, supervise=False)
    resumed = pool2.recover({"flow": flow})
    assert sorted(r.run_id for r in resumed) == [r.run_id for r in runs]
    pool2.drain(until=HORIZON)
    for r in runs:
        got = pool2.get_run(r.run_id)
        assert got.status == RUN_SUCCEEDED
        assert got.context["b"]["details"]["echo_string"] == \
            r.context["a"]["details"]["echo_string"]


# --------------------------------------------------------------- triggers

def test_trigger_journal_ownership_rehashes(tmp_path):
    from repro.core.flows_service import FlowsService
    from repro.core.queues import QueueService

    clock = VirtualClock()
    registry = ActionRegistry()
    registry.register(EchoProvider(clock=clock))
    registry.register(SleepProvider(clock=clock))
    queues = QueueService(clock=clock)
    svc = FlowsService(registry, clock=clock, shards=2, queues=queues,
                       journal_path=str(tmp_path / "journal.jsonl"))
    supervisor = svc.enable_supervision(heartbeat_interval=50.0,
                                        heartbeat_timeout=200.0)
    record = svc.publish_flow(
        {"StartAt": "E",
         "States": {"E": {"Type": "Action", "ActionUrl": "ap://echo",
                          "Parameters": {"echo_string.$": "$.path"},
                          "End": True}}},
        title="triggered",
    )
    q = queues.create_queue("instrument")
    # pick a trigger id homed on the shard we will kill
    tid = next(f"trig-{i}" for i in range(64) if shard_index(f"trig-{i}", 2) == 1)
    svc.create_trigger(q.queue_id, 'filename.endswith(".tiff")',
                       record.flow_id,
                       transform={"path": "filename"}, trigger_id=tid)
    svc.enable_trigger(tid)
    queues.send(q.queue_id, {"filename": "a.tiff"})
    svc.engine.drain(until=60.0)

    supervisor.fail_shard(1, reason="test")
    assert supervisor.stats["triggers_rehomed"] >= 1
    # the re-journaled image landed on the trigger's new live home
    host = svc.engine.journal_for(tid)
    assert any(rec.get("type") == "trigger_rehomed"
               and rec.get("trigger_id") == tid
               for rec in host.records())
    # the trigger keeps firing after the failover
    queues.send(q.queue_id, {"filename": "b.tiff"})
    svc.engine.drain(until=1000.0)
    fired = [r for r in svc.engine.runs.values() if r.parent is None]
    assert len(fired) == 2
    assert all(r.status == RUN_SUCCEEDED for r in fired)


# --------------------------------------------------------- metered tenants

def test_metered_runs_survive_failover_with_admission_credit():
    from repro.core.auth import AuthService, Caller
    from repro.core.flows_service import FlowsService

    clock = VirtualClock()
    auth = AuthService(clock=clock)
    registry = ActionRegistry()
    registry.register(EchoProvider(clock=clock, auth=auth))
    registry.register(SleepProvider(clock=clock, auth=auth))
    svc = FlowsService(registry, clock=clock, auth=auth, shards=2,
                       admission_window=2)
    supervisor = svc.enable_supervision(heartbeat_interval=50.0,
                                        heartbeat_timeout=200.0)
    auth.register_tenant("acme")
    auth.create_identity("alice")
    auth.assign_tenant("alice", "acme")
    record = svc.publish_flow(CHAIN, owner="root",
                              starters=["all_authenticated_users"])
    auth.grant_consent("alice", record.scope)
    token = auth.issue_token("alice", record.scope)
    caller = Caller(identity=auth.get_identity("alice"),
                    tokens={record.scope: token})
    runs = [svc.run_flow(record.flow_id, {"msg": str(i)}, caller=caller)
            for i in range(8)]
    svc.engine.drain(until=10.0)  # window=2: most runs still deferred

    supervisor.fail_shard(1, reason="test")
    svc.engine.drain(until=HORIZON)
    # the window kept cycling across the takeover: deferred runs were
    # admitted by slots credited back from re-homed completions
    assert all(r.status == RUN_SUCCEEDED for r in runs)
