import os

import pytest

from repro.core.actions import ACTIVE, FAILED, SUCCEEDED
from repro.core.clock import VirtualClock
from repro.core.errors import ActionUnknown, Forbidden
from repro.core.providers import (
    ComputeProvider,
    DOIProvider,
    EchoProvider,
    EmailProvider,
    SearchProvider,
    SleepProvider,
    TransferProvider,
    UserSelectionProvider,
)


def test_echo_synchronous_and_introspection():
    p = EchoProvider(clock=VirtualClock())
    doc = p.introspect()
    assert doc["globus_auth_scope"].startswith("urn:repro:scopes:echo")
    assert "input_schema" in doc
    st = p.run({"echo_string": "hi"})
    assert st.status == SUCCEEDED
    assert st.details["echo_string"] == "hi"


def test_request_id_idempotency():
    p = EchoProvider(clock=VirtualClock())
    a = p.run({"echo_string": "x"}, request_id="req-1")
    b = p.run({"echo_string": "y"}, request_id="req-1")
    assert a.action_id == b.action_id
    assert b.details["echo_string"] == "x"  # original action returned


def test_release_then_unknown():
    p = EchoProvider(clock=VirtualClock())
    st = p.run({"echo_string": "x"})
    p.release(st.action_id)
    with pytest.raises(ActionUnknown):
        p.status(st.action_id)


def test_retention_expiry_gcs_completed_actions():
    """Regression: RETENTION_SECONDS was declared but never enforced — a
    long-lived provider accumulated every completed action forever.  Past
    retention, completed state is swept on access and the id becomes
    unrecognized, exactly like an explicit release."""
    clock = VirtualClock()
    p = EchoProvider(clock=clock)
    p.retention_seconds = 100.0
    done = p.run({"echo_string": "old"}, request_id="req-old")
    assert p.run({"echo_string": "x"}, request_id="req-old").action_id == \
        done.action_id  # idempotent while retained
    clock.advance(50.0)
    assert p.status(done.action_id).status == SUCCEEDED  # still retained

    clock.advance(51.0)  # past completion_time + retention
    with pytest.raises(ActionUnknown):
        p.status(done.action_id)
    assert p.stats["expired"] == 1
    # the idempotency mapping is dropped with the action: a re-submitted
    # request_id starts a NEW action instead of resurrecting the old one
    fresh = p.run({"echo_string": "new"}, request_id="req-old")
    assert fresh.action_id != done.action_id
    assert fresh.details["echo_string"] == "new"
    # internal maps are actually bounded (nothing leaks)
    assert done.action_id not in p._actions


def test_retention_expiry_spares_active_and_released_actions():
    clock = VirtualClock()
    p = SleepProvider(clock=clock)
    p.retention_seconds = 10.0
    active = p.run({"seconds": 1e9})  # stays ACTIVE "forever"
    quick = p.run({"seconds": 0.0})
    clock.advance(1.0)
    assert p.status(quick.action_id).status == SUCCEEDED
    released = p.release(quick.action_id)
    assert released.status == SUCCEEDED
    clock.advance(1000.0)
    # released state is gone, but the sweep skips it without double-counting,
    # and ACTIVE actions are never expired no matter how old
    assert p.status(active.action_id).status == ACTIVE
    assert p.stats["expired"] == 0


def test_status_reports_remaining_release_after():
    clock = VirtualClock(start=1000.0)
    p = EchoProvider(clock=clock)
    p.retention_seconds = 100.0
    st = p.run({"echo_string": "hi"})  # completes synchronously at t=1000
    assert st.release_after == 100.0
    clock.advance(30.0)
    assert p.status(st.action_id).release_after == 70.0
    assert p.status(st.action_id).as_dict()["release_after"] == 70.0


def test_release_active_forbidden_then_cancel():
    clock = VirtualClock()
    p = SleepProvider(clock=clock)
    st = p.run({"seconds": 100})
    assert st.status == ACTIVE
    with pytest.raises(Forbidden):
        p.release(st.action_id)
    st2 = p.cancel(st.action_id)
    assert st2.status == FAILED
    p.release(st.action_id)


def test_sleep_completes_with_clock():
    clock = VirtualClock()
    p = SleepProvider(clock=clock)
    st = p.run({"seconds": 10})
    assert p.status(st.action_id).status == ACTIVE
    clock.advance(10.0)
    assert p.status(st.action_id).status == SUCCEEDED


def test_transfer_roundtrip(tmp_path):
    clock = VirtualClock()
    p = TransferProvider(clock=clock, workspace=str(tmp_path))
    src = p.create_endpoint("beamline", bandwidth_bps=1e6, latency_s=1.0)
    p.create_endpoint("hpc", bandwidth_bps=1e9, latency_s=0.5)
    with open(os.path.join(src.root, "scan.raw"), "wb") as fh:
        fh.write(b"z" * 2_000_000)
    st = p.run(
        {
            "operation": "transfer",
            "source_endpoint": "beamline",
            "destination_endpoint": "hpc",
            "source_path": "scan.raw",
            "destination_path": "in/scan.raw",
        }
    )
    assert st.status == ACTIVE  # modeled duration: 1.5 + 2e6/1e6 = 3.5s
    clock.advance(3.4)
    assert p.status(st.action_id).status == ACTIVE
    clock.advance(0.2)
    final = p.status(st.action_id)
    assert final.status == SUCCEEDED
    assert final.details["bytes"] == 2_000_000
    assert os.path.exists(os.path.join(tmp_path, "hpc", "in", "scan.raw"))


def test_transfer_ls_mkdir_delete_permissions(tmp_path):
    clock = VirtualClock()
    p = TransferProvider(clock=clock, workspace=str(tmp_path))
    p.create_endpoint("store", latency_s=0.0)
    st = p.run({"operation": "mkdir", "endpoint": "store", "path": "data"})
    assert st.status == SUCCEEDED
    st = p.run({"operation": "ls", "endpoint": "store", "path": "/"})
    assert [e["name"] for e in st.details["entries"]] == ["data"]
    st = p.run({"operation": "set_permissions", "endpoint": "store",
                 "path": "/", "principals": ["user:alice"]})
    assert st.status == SUCCEEDED
    assert p.endpoint("store").writers == {"alice"}
    st = p.run({"operation": "delete", "endpoint": "store", "path": "data"})
    assert st.status == SUCCEEDED
    st = p.run({"operation": "delete", "endpoint": "store", "path": "data"})
    assert st.status == FAILED  # already gone


def test_transfer_missing_source_fails(tmp_path):
    p = TransferProvider(clock=VirtualClock(), workspace=str(tmp_path))
    p.create_endpoint("a")
    p.create_endpoint("b")
    st = p.run(
        {
            "operation": "transfer",
            "source_endpoint": "a",
            "destination_endpoint": "b",
            "source_path": "nope",
            "destination_path": "x",
        }
    )
    assert st.status == FAILED


def test_compute_inline_and_modeled_duration():
    clock = VirtualClock()
    p = ComputeProvider(clock=clock)
    eid = p.register_endpoint("hpc", mode="inline")
    fid = p.register_function(
        lambda x: x * 2, name="double", modeled_duration=lambda kw: 30.0
    )
    st = p.run({"endpoint_id": eid, "function_id": fid, "kwargs": {"x": 21}})
    assert st.status == ACTIVE
    clock.advance(30.0)
    final = p.status(st.action_id)
    assert final.status == SUCCEEDED
    assert final.details["results"] == [42]


def test_compute_bundled_tasks_and_errors():
    p = ComputeProvider(clock=VirtualClock())
    eid = p.register_endpoint("hpc")
    f1 = p.register_function(lambda: 1)
    f2 = p.register_function(lambda: 1 / 0)
    st = p.run({"tasks": [{"endpoint_id": eid, "function_id": f1, "kwargs": {}}]})
    assert st.status == SUCCEEDED and st.details["results"] == [1]
    st = p.run({"endpoint_id": eid, "function_id": f2, "kwargs": {}})
    assert st.status == FAILED
    assert "ZeroDivisionError" in st.details["error"]


def test_search_ingest_query_delete(tmp_path):
    clock = VirtualClock()
    p = SearchProvider(clock=clock, persist_dir=str(tmp_path))
    st = p.run({"operation": "ingest", "index": "ssx", "subject": "s1",
                 "entry": {"sample": "lysozyme", "hits": 12}})
    clock.advance(1.0)
    assert p.status(st.action_id).status == SUCCEEDED
    st = p.run({"operation": "query", "index": "ssx", "q": "lysozyme"})
    clock.advance(1.0)
    st = p.status(st.action_id)
    assert st.details["count"] == 1
    # persistence survives a restart
    p2 = SearchProvider(clock=VirtualClock(), persist_dir=str(tmp_path))
    assert "s1" in p2.entries("ssx")
    st = p.run({"operation": "delete", "index": "ssx", "subject": "s1"})
    clock.advance(1.0)
    assert p.status(st.action_id).details["deleted"] is True


def test_email_templating():
    clock = VirtualClock()
    p = EmailProvider(clock=clock)
    st = p.run(
        {
            "to": "pi@lab.edu",
            "subject": "Run ${run_id} done",
            "body": "Loss: ${metrics.loss}",
            "template_values": {"run_id": "r-1", "metrics": {"loss": 2.5}},
        }
    )
    clock.advance(1.0)
    assert p.status(st.action_id).status == SUCCEEDED
    [msg] = p.outbox
    assert msg["subject"] == "Run r-1 done"
    assert msg["body"] == "Loss: 2.5"
    # unknown placeholders left intact
    st = p.run({"to": "x", "body": "${missing}", "template_values": {}})
    assert p.outbox[-1]["body"] == "${missing}"


def test_doi_minting_sequence(tmp_path):
    clock = VirtualClock()
    p = DOIProvider(clock=clock, namespace="10.5555",
                    persist_path=str(tmp_path / "dois.json"))
    st1 = p.run({"url": "https://cat/1", "metadata": {"title": "DS1"}})
    st2 = p.run({"url": "https://cat/2"})
    clock.advance(1.0)
    d1 = p.status(st1.action_id).details["doi"]
    d2 = p.status(st2.action_id).details["doi"]
    assert d1 == "10.5555/repro.000001" and d2 == "10.5555/repro.000002"
    assert p.resolve(d1)["metadata"] == {"title": "DS1"}
    # sequence persists across restart
    p2 = DOIProvider(clock=VirtualClock(), namespace="10.5555",
                     persist_path=str(tmp_path / "dois.json"))
    st3 = p2.run({"url": "https://cat/3"})
    assert st3.details["doi"] == "10.5555/repro.000003"


def test_user_selection_respondent_restriction():
    p = UserSelectionProvider(clock=VirtualClock())
    st = p.run({"options": ["a", "b"], "respondents": ["curator"]})
    with pytest.raises(Forbidden):
        p.respond(st.action_id, "a", responder="rando")
    p.respond(st.action_id, 1, responder="curator")
    assert p.status(st.action_id).details["selection"] == "b"


def test_schema_validation_rejects_bad_input():
    p = SleepProvider(clock=VirtualClock())
    from repro.core.schema import ValidationFailure

    with pytest.raises(ValidationFailure):
        p.run({})
    with pytest.raises(ValidationFailure):
        p.run({"seconds": -1})
