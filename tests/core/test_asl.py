import pytest

from repro.core import asl
from repro.core.errors import FlowValidationError


def _min_flow(**extra_states):
    states = {
        "Go": {"Type": "Pass", "End": True},
        **extra_states,
    }
    return {"StartAt": "Go", "States": states}


def test_parse_minimal():
    flow = asl.parse(_min_flow())
    assert flow.start_at == "Go"
    assert flow.states["Go"].kind == "Pass"


def test_paper_example_flow():
    """The five-state skeleton of paper §4.2.1 parses and validates."""
    definition = {
        "StartAt": "Transfer",
        "States": {
            "Transfer": {
                "Type": "Action",
                "ActionUrl": "ap://transfer",
                "Parameters": {"source_path.$": "$.input.src"},
                "ResultPath": "$.TransferResult",
                "Next": "Validate",
            },
            "Validate": {
                "Type": "Action",
                "ActionUrl": "ap://compute",
                "WaitTime": 7200,
                "ExceptionOnActionFailure": True,
                "Catch": [
                    {
                        "ErrorEquals": ["ActionFailedException"],
                        "ResultPath": "$.ValidFailureInfo",
                        "Next": "Failure",
                    }
                ],
                "ResultPath": "$.Valid",
                "Next": "Check",
            },
            "Check": {
                "Type": "Choice",
                "Choices": [
                    {"Variable": "$.Valid.details.ok", "BooleanEquals": True,
                     "Next": "Publish"}
                ],
                "Default": "Failure",
            },
            "Publish": {
                "Type": "Action",
                "ActionUrl": "ap://search",
                "RunAs": "ComputeProvider",
                "End": True,
            },
            "Failure": {"Type": "Fail", "Error": "ValidationFailed",
                        "Cause": "input did not validate"},
        },
    }
    flow = asl.parse(definition)
    assert flow.states["Validate"].wait_time == 7200
    assert flow.states["Validate"].catch[0].next == "Failure"
    assert flow.states["Publish"].run_as == "ComputeProvider"
    assert asl.action_urls(flow) == ["ap://transfer", "ap://compute", "ap://search"]
    assert asl.run_as_roles(flow) == ["ComputeProvider"]


@pytest.mark.parametrize(
    "mutate",
    [
        lambda d: d.pop("StartAt"),
        lambda d: d.update(StartAt="Missing"),
        lambda d: d["States"].update(Bad={"Type": "Nope", "End": True}),
        lambda d: d["States"].update(
            Orphan={"Type": "Pass", "Next": "NoSuchState"}
        ),
        lambda d: d["States"]["Go"].pop("End"),
        lambda d: d["States"]["Go"].update(Next="Go2", End=True)
        or d["States"].update(Go2={"Type": "Pass", "End": True}),
    ],
)
def test_validation_failures(mutate):
    doc = _min_flow()
    mutate(doc)
    with pytest.raises(FlowValidationError):
        asl.parse(doc)


def test_unreachable_states_rejected():
    doc = _min_flow(Island={"Type": "Pass", "End": True})
    with pytest.raises(FlowValidationError) as e:
        asl.parse(doc)
    assert "unreachable" in str(e.value)


def test_choice_rules_evaluate():
    rule = asl._parse_choice_rule(
        {
            "And": [
                {"Variable": "$.a", "NumericGreaterThan": 5},
                {"Not": {"Variable": "$.b", "StringEquals": "x"}},
            ],
            "Next": "T",
        },
        "t",
        top=True,
    )
    assert rule.evaluate({"a": 6, "b": "y"})
    assert not rule.evaluate({"a": 6, "b": "x"})
    assert not rule.evaluate({"a": 5, "b": "y"})
    # missing variable -> false, not an error
    assert not rule.evaluate({"b": "y"})


def test_choice_ispresent_and_matches():
    present = asl._parse_choice_rule(
        {"Variable": "$.x", "IsPresent": True, "Next": "T"}, "t", True
    )
    assert present.evaluate({"x": None})
    assert not present.evaluate({})
    glob = asl._parse_choice_rule(
        {"Variable": "$.f", "StringMatches": "*.tiff", "Next": "T"}, "t", True
    )
    assert glob.evaluate({"f": "a.tiff"})
    assert not glob.evaluate({"f": "a.h5"})


def test_numeric_type_mismatch_is_false():
    rule = asl._parse_choice_rule(
        {"Variable": "$.a", "NumericEquals": 1, "Next": "T"}, "t", True
    )
    assert not rule.evaluate({"a": "1"})
    assert not rule.evaluate({"a": True})


def test_wait_state_needs_exactly_one_duration():
    with pytest.raises(FlowValidationError):
        asl.parse(
            {"StartAt": "W", "States": {"W": {"Type": "Wait", "End": True}}}
        )
    with pytest.raises(FlowValidationError):
        asl.parse(
            {
                "StartAt": "W",
                "States": {
                    "W": {"Type": "Wait", "Seconds": 1, "SecondsPath": "$.s",
                          "End": True}
                },
            }
        )


def test_parallel_branches_parse():
    doc = {
        "StartAt": "P",
        "States": {
            "P": {
                "Type": "Parallel",
                "Branches": [
                    {"StartAt": "A", "States": {"A": {"Type": "Pass", "End": True}}},
                    {"StartAt": "B", "States": {"B": {"Type": "Pass", "End": True}}},
                ],
                "ResultPath": "$.branches",
                "Next": "Done",
            },
            "Done": {"Type": "Succeed"},
        },
    }
    flow = asl.parse(doc)
    assert len(flow.states["P"].branches) == 2


def test_parallel_catch_missing_keys_is_validation_error():
    """Regression (latent-bug sweep): a Parallel Catch entry without
    ErrorEquals/Next used to raise a bare KeyError at publish time instead
    of a FlowValidationError like Action states."""
    import pytest

    from repro.core.errors import FlowValidationError

    doc = {
        "StartAt": "P",
        "States": {
            "P": {
                "Type": "Parallel",
                "Branches": [
                    {"StartAt": "A",
                     "States": {"A": {"Type": "Pass", "End": True}}},
                ],
                "Catch": [{"Next": "Done"}],  # missing ErrorEquals
                "Next": "Done",
            },
            "Done": {"Type": "Succeed"},
        },
    }
    with pytest.raises(FlowValidationError):
        asl.parse(doc)


def test_map_state_parses_and_compiles():
    doc = {
        "StartAt": "M",
        "States": {
            "M": {
                "Type": "Map",
                "ItemsPath": "$.xs",
                "MaxConcurrency": 8,
                "ToleratedFailureCount": 1,
                "ItemSelector": {"v.$": "$.item"},
                "Iterator": {"StartAt": "A",
                             "States": {"A": {"Type": "Pass", "End": True}}},
                "ResultPath": "$.out",
                "End": True,
            },
        },
    }
    flow = asl.parse(doc)
    st = flow.states["M"]
    assert st.kind == "Map"
    assert st.max_concurrency == 8
    assert st.tolerated_failures == 1
    assert st.iterator is not None and "A" in st.iterator.states
    assert st.items_for({"xs": [1, 2]}) == [1, 2]
    assert st.item_input({}, 5, 0) == {"v": 5}
