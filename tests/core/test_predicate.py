import pytest

from repro.core import predicate as pl


MSG = {
    "filename": "scan_0042.tiff",
    "size": 2048,
    "files": ["a.h5", "b.h5"],
    "meta": {"beamline": "8-ID", "hits": 7},
    "ok": True,
}


@pytest.mark.parametrize(
    "expr,expected",
    [
        ('filename.endswith(".tiff")', True),
        ('filename.endswith(".h5")', False),
        ("size > 1024 and ok", True),
        ("size > 1024 and not ok", False),
        ("len(files) == 2", True),
        ('meta.beamline == "8-ID"', True),
        ('meta["hits"] >= 7', True),
        ('"a.h5" in files', True),
        ("size / 2 == 1024.0", True),
        ("min(3, size) == 3", True),
        ('filename.split("_")[0] == "scan"', True),
        ("(size > 10000) or (meta.hits < 10)", True),
        ("1 < meta.hits < 10", True),
    ],
)
def test_predicates(expr, expected):
    assert pl.matches(expr, MSG) is expected


def test_transform():
    out = pl.transform(
        {"number_of_files": "len(files)", "label": 'filename.replace(".tiff", "")'},
        MSG,
    )
    assert out == {"number_of_files": 2, "label": "scan_0042"}


@pytest.mark.parametrize(
    "evil",
    [
        "__import__('os')",
        "().__class__",
        "open('/etc/passwd')",
        "filename.__class__",
        "lambda: 1",
        "[x for x in files]",
        "exec('1')",
        "meta.items",  # attribute exists but unknown name path fails first? -> allowed method actually
    ],
)
def test_unsafe_rejected(evil):
    if evil == "meta.items":
        # dict method access is whitelisted; calling it is fine
        assert pl.evaluate("len(meta.items())", MSG) == 2
        return
    with pytest.raises(pl.PredicateError):
        pl.evaluate(evil, MSG)


def test_unknown_name_no_match():
    assert pl.matches("nope > 1", MSG) is False


def test_huge_exponent_rejected():
    with pytest.raises(pl.PredicateError):
        pl.evaluate("2 ** 9999", MSG)


def test_compile_reuse():
    tree = pl.compile_expr("size > 1000")
    assert pl.matches(tree, MSG)
    assert not pl.matches(tree, {"size": 10})
