"""EngineShardPool: routing, cross-shard determinism, sharded recovery."""

import json
import os

import pytest

from repro.core import asl
from repro.core.actions import ActionRegistry
from repro.core.clock import VirtualClock
from repro.core.engine import RUN_ACTIVE, RUN_SUCCEEDED, FlowEngine
from repro.core.errors import NotFound
from repro.core.journal import Journal, segment_path
from repro.core.shard_pool import EngineShardPool, placement_key, shard_index
from repro.core.providers import EchoProvider, SleepProvider

CHAIN = {
    "StartAt": "A",
    "States": {
        "A": {"Type": "Action", "ActionUrl": "ap://echo",
              "Parameters": {"echo_string.$": "$.msg"},
              "ResultPath": "$.a", "Next": "Pause"},
        "Pause": {"Type": "Action", "ActionUrl": "ap://sleep",
                  "Parameters": {"seconds": 50.0},
                  "ResultPath": "$.pause", "Next": "B"},
        "B": {"Type": "Action", "ActionUrl": "ap://echo",
              "Parameters": {"echo_string.$": "$.a.details.echo_string"},
              "ResultPath": "$.b", "End": True},
    },
}

PARALLEL = {
    "StartAt": "Fan",
    "States": {
        "Fan": {
            "Type": "Parallel",
            "ResultPath": "$.branches",
            "Branches": [
                {"StartAt": "E0",
                 "States": {"E0": {"Type": "Action", "ActionUrl": "ap://echo",
                                    "Parameters": {"echo_string": "b0"},
                                    "End": True}}},
                {"StartAt": "S1",
                 "States": {"S1": {"Type": "Action", "ActionUrl": "ap://sleep",
                                    "Parameters": {"seconds": 5.0},
                                    "End": True}}},
            ],
            "End": True,
        }
    },
}


def make_pool(num_shards, journal_path=None):
    clock = VirtualClock()
    registry = ActionRegistry()
    registry.register(EchoProvider(clock=clock))
    registry.register(SleepProvider(clock=clock))
    pool = EngineShardPool(
        registry, num_shards=num_shards, clock=clock, journal_path=journal_path
    )
    return pool, clock


# ---------------------------------------------------------------- routing

def test_shard_index_stable_and_in_range():
    for n in (1, 2, 4, 8):
        for i in range(50):
            rid = f"run-{i:04x}"
            assert 0 <= shard_index(rid, n) < n
            assert shard_index(rid, n) == shard_index(rid, n)


def test_parallel_children_colocate_with_parent():
    for n in (2, 4, 8):
        assert shard_index("run-abc.b0", n) == shard_index("run-abc", n)
        assert shard_index("run-abc.b1.b2", n) == shard_index("run-abc", n)


def test_placement_key_strips_branches_keeps_map_items():
    """Branch segments (``.bN``) co-locate; Map item segments (``.mN``)
    give each item child its own deterministic home."""
    assert placement_key("run-abc") == "run-abc"
    assert placement_key("run-abc.b0") == "run-abc"
    assert placement_key("run-abc.b1.b2") == "run-abc"
    assert placement_key("run-abc.m3") == "run-abc.m3"
    assert placement_key("run-abc.b1.m2") == "run-abc.m2"
    assert placement_key("run-abc.m2.b1") == "run-abc.m2"
    # only "m<digits>" is a Map segment; anything else folds to the parent
    assert placement_key("run-abc.mx") == "run-abc"
    assert placement_key("run-abc.m") == "run-abc"


def test_map_children_spread_across_shards():
    for n in (2, 4, 8):
        homes = {shard_index(f"run-abc.m{i}", n) for i in range(32)}
        assert homes <= set(range(n))
        assert len(homes) > 1  # a fan-out never saturates one shard


def test_runs_route_to_owning_shard():
    pool, _ = make_pool(4)
    flow = asl.parse(CHAIN)
    runs = [pool.start_run(flow, {"msg": f"m{i}"}) for i in range(16)]
    for run in runs:
        home = pool.engines[shard_index(run.run_id, 4)]
        assert run.run_id in home.runs
        assert pool.get_run(run.run_id) is run
    pool.drain()
    assert all(r.status == RUN_SUCCEEDED for r in runs)
    # every run executed exactly one engine's state machine; totals add up
    assert pool.stats["runs_started"] == 16
    assert pool.stats["runs_succeeded"] == 16
    assert sum(e.stats["runs_started"] for e in pool.engines) == 16


def test_bad_shard_configs_rejected():
    registry = ActionRegistry()
    with pytest.raises(ValueError):
        EngineShardPool(registry, num_shards=0)
    with pytest.raises(ValueError):
        EngineShardPool(registry, num_shards=2, journal=Journal())
    with pytest.raises(ValueError):
        EngineShardPool(registry, num_shards=2, journals=[Journal()])


# ----------------------------------------------------- determinism contract

def _run_suite_on(num_shards):
    """Run a fixed workload; return terminal (status, context) per label."""
    pool, _ = make_pool(num_shards)
    flow = asl.parse(CHAIN)
    par = asl.parse(PARALLEL)
    runs = {}
    for i in range(8):
        runs[f"chain{i}"] = pool.start_run(flow, {"msg": f"m{i}"})
    runs["par"] = pool.start_run(par, {})
    pool.drain()
    return {
        label: (r.status, r.context, r.completion_time)
        for label, r in runs.items()
    }


def test_identical_semantics_across_shard_counts():
    """VirtualClock runs produce the same transitions, outputs, and
    completion times for every shard count."""
    baseline = _run_suite_on(1)
    for n in (2, 4, 8):
        outcome = _run_suite_on(n)
        for label, (status, context, done_at) in baseline.items():
            got_status, got_context, got_done = outcome[label]
            assert got_status == status == RUN_SUCCEEDED
            assert got_done == done_at
            # action ids differ between processes/pools; compare the parts
            # of the context the flow semantics determine
            if label.startswith("chain"):
                assert got_context["a"]["details"] == context["a"]["details"]
                assert got_context["b"]["details"] == context["b"]["details"]


def test_pool_drain_is_global_time_order():
    pool, clock = make_pool(4)
    flow = asl.parse(CHAIN)
    runs = [pool.start_run(flow, {"msg": str(i)}) for i in range(8)]
    # partial drain: nothing may have executed past the time bound
    pool.drain(until=10.0)
    assert clock.now() <= 10.0
    assert all(r.status == RUN_ACTIVE for r in runs)
    assert all(r.current_state == "Pause" for r in runs)
    pool.drain()
    assert all(r.status == RUN_SUCCEEDED for r in runs)


def test_run_to_completion_drains_other_shards_too():
    """A run whose dependency lives on another shard still completes."""
    pool, _ = make_pool(4)
    flow = asl.parse(CHAIN)
    runs = [pool.start_run(flow, {"msg": str(i)}) for i in range(8)]
    done = pool.run_to_completion(runs[-1].run_id)
    assert done.status == RUN_SUCCEEDED


# --------------------------------------------------------- sharded recovery

def test_kill_pool_midflight_recover_per_shard(tmp_path):
    """Kill a 4-shard pool mid-flight; recover each shard from its own
    journal segment; every run reaches the same terminal state as an
    uninterrupted execution."""
    flow = asl.parse(CHAIN)

    # uninterrupted reference execution
    ref_pool, _ = make_pool(4)
    ref_runs = {}
    for i in range(12):
        r = ref_pool.start_run(flow, {"msg": f"m{i}"}, run_id=f"run-{i:04d}")
        ref_runs[r.run_id] = r
    ref_pool.drain()

    # interrupted execution: crash while every run sleeps in "Pause"
    path = str(tmp_path / "journal.jsonl")
    pool1, _ = make_pool(4, journal_path=path)
    for i in range(12):
        pool1.start_run(flow, {"msg": f"m{i}"}, run_id=f"run-{i:04d}")
    pool1.drain(until=10.0)
    statuses = [pool1.get_run(f"run-{i:04d}").status for i in range(12)]
    assert statuses == [RUN_ACTIVE] * 12  # killed mid-flight

    # each shard wrote only its own runs to its own segment
    seen = set()
    for i in range(4):
        seg = segment_path(path, i, 4)
        assert os.path.exists(seg)
        with open(seg) as fh:
            for line in fh:
                rid = line.split('"run_id":"')[1].split('"')[0]
                root = rid.split(".", 1)[0]
                assert shard_index(root, 4) == i
                seen.add(root)
    assert len(seen) == 12

    # restart: fresh pool + providers over the same segments
    pool2, _ = make_pool(4, journal_path=path)
    resumed = pool2.recover({"flow": flow})
    assert sorted(r.run_id for r in resumed) == sorted(ref_runs)
    pool2.drain()
    for rid, ref in ref_runs.items():
        got = pool2.get_run(rid)
        assert got.status == ref.status == RUN_SUCCEEDED
        assert got.context["a"]["details"] == ref.context["a"]["details"]
        assert got.context["b"]["details"] == ref.context["b"]["details"]


def test_recovery_skips_finished_runs(tmp_path):
    path = str(tmp_path / "journal.jsonl")
    flow = asl.parse(CHAIN)
    pool1, _ = make_pool(4, journal_path=path)
    done = pool1.start_run(flow, {"msg": "done"})
    pool1.run_to_completion(done.run_id)
    live = pool1.start_run(flow, {"msg": "live"})
    pool1.drain(until=10.0)
    assert done.status == RUN_SUCCEEDED and live.status == RUN_ACTIVE

    pool2, _ = make_pool(4, journal_path=path)
    resumed = pool2.recover({"flow": flow})
    assert [r.run_id for r in resumed] == [live.run_id]


# ------------------------------------------------------------- aggregation

def test_runs_view_merges_shards_in_submission_order():
    pool, _ = make_pool(4)
    flow = asl.parse(CHAIN)
    expected = [pool.start_run(flow, {"msg": str(i)}).run_id for i in range(10)]
    top_level = [
        rid for rid, run in pool.runs.items() if run.parent is None
    ]
    assert top_level == expected


# ------------------------------------------- regression: seq assignment race

def test_seq_set_at_construction_and_journaled(tmp_path):
    """Regression: ``seq`` used to be stamped on the *returned* run, racing
    its first transitions — a run's ``run_created`` record could journal the
    default 0.  It is now handed into ``FlowEngine.start_run`` so the run is
    born with it, the journal records it, and recovery restores it."""
    path = str(tmp_path / "journal.jsonl")
    flow = asl.parse(CHAIN)
    pool1, _ = make_pool(4, journal_path=path)
    expected = [
        pool1.start_run(flow, {"msg": str(i)}, run_id=f"run-{i:04d}").run_id
        for i in range(8)
    ]
    assert [pool1.get_run(rid).seq for rid in expected] == list(range(1, 9))
    pool1.drain(until=10.0)  # "crash" mid-flight, every run in Pause

    seqs = {}
    for i in range(4):
        with open(segment_path(path, i, 4)) as fh:
            for line in fh:
                rec = json.loads(line)
                if rec.get("type") == "run_created":
                    seqs[rec["run_id"]] = rec["seq"]
    assert [seqs[rid] for rid in expected] == list(range(1, 9))

    pool2, _ = make_pool(4, journal_path=path)
    pool2.recover({"flow": flow})
    assert [pool2.get_run(rid).seq for rid in expected] == list(range(1, 9))
    # the merged runs view sorts by the recovered seq: submission order holds
    assert list(pool2.runs) == expected


def test_engine_start_run_accepts_seq():
    clock = VirtualClock()
    registry = ActionRegistry()
    registry.register(EchoProvider(clock=clock))
    registry.register(SleepProvider(clock=clock))
    engine = FlowEngine(registry, clock=clock)
    run = engine.start_run(asl.parse(CHAIN), {"msg": "x"}, run_id="r", seq=7)
    assert run.seq == 7


# --------------------------------------- regression: wake_run TOCTOU contract

PARK = {
    "StartAt": "Park",
    "States": {
        "Park": {"Type": "Wait", "Seconds": 7000.0, "Next": "Done"},
        "Done": {"Type": "Pass", "Result": {"ok": True},
                 "ResultPath": "$.done", "End": True},
    },
}


def make_parking_pool(num_shards=2):
    clock = VirtualClock()
    registry = ActionRegistry()
    registry.register(EchoProvider(clock=clock))
    registry.register(SleepProvider(clock=clock))
    return EngineShardPool(registry, num_shards=num_shards, clock=clock,
                           passivate_after=0.0)


def test_wake_run_contract_sequential():
    pool = make_parking_pool()
    run = pool.start_run(asl.parse(PARK), {}, flow_id="f", run_id="run-park")
    pool.drain(until=10.0)
    assert run.run_id in pool.dormant

    assert pool.wake_run(run.run_id) is True   # this call rehydrated it
    assert pool.wake_run(run.run_id) is False  # already resident
    assert pool.wake_run("run-nope") is False  # unknown
    pool.drain()
    assert pool.get_run(run.run_id).status == RUN_SUCCEEDED


def test_wake_run_raced_by_timer_returns_false():
    """Regression: wake_run used to check dormancy, then pop — a wake that
    landed between the two made it claim a rehydration it never performed.
    The pop is the atomic claim now: a raced wake_run observes the miss and
    returns False, and the run is resumed exactly once."""
    pool = make_parking_pool()
    run = pool.start_run(asl.parse(PARK), {}, flow_id="f", run_id="run-park")
    pool.drain(until=10.0)
    engine = pool.shard_of(run.run_id)
    assert run.run_id in engine.dormant

    real_pop = engine._pop_stub
    raced = []

    def racy_pop(run_id):
        if not raced:  # the timer wake fires inside wake_run's window
            raced.append(run_id)
            engine._wake_dormant(run_id)
        return real_pop(run_id)

    engine._pop_stub = racy_pop
    try:
        assert engine.wake_run(run.run_id) is False  # lost the race
    finally:
        engine._pop_stub = real_pop
    assert raced == [run.run_id]  # the injected race did happen
    assert run.run_id in engine.runs
    assert run.run_id not in engine.dormant
    pool.drain()
    assert pool.get_run(run.run_id).status == RUN_SUCCEEDED
    assert pool.get_run(run.run_id).context["done"] == {"ok": True}


# ---------------------------------- regression: O(1) foreign-residency index

def test_recover_mismatched_journals_registers_foreign_index():
    """Explicit ``journals=`` whose contents don't match hash placement:
    recovery registers the off-home runs in the foreign-residency index, so
    facade lookups resolve without the full-pool scan ``_owner`` used to
    fall back to — and unknown ids still raise NotFound from the home."""
    def pool_with(journals):
        clock = VirtualClock()
        registry = ActionRegistry()
        registry.register(EchoProvider(clock=clock))
        registry.register(SleepProvider(clock=clock))
        return EngineShardPool(registry, num_shards=2, clock=clock,
                               journals=journals)

    j0, j1 = Journal(), Journal()
    flow = asl.parse(CHAIN)
    pool1 = pool_with([j0, j1])
    by_home, i = {}, 0
    while len(by_home) < 2:  # one run homed on each shard
        rid = f"run-{i:02d}"
        by_home.setdefault(shard_index(rid, 2), rid)
        i += 1
    for rid in by_home.values():
        pool1.start_run(flow, {"msg": rid}, flow_id="f", run_id=rid)
    pool1.drain(until=10.0)  # crash mid-flight

    pool2 = pool_with([j1, j0])  # segments swapped: every run is off-home
    resumed = pool2.recover({"f": flow})
    assert sorted(r.run_id for r in resumed) == sorted(by_home.values())
    assert pool2._foreign == {by_home[0]: 1, by_home[1]: 0}
    for home, rid in by_home.items():
        assert rid not in pool2.engines[home].runs
        assert pool2.get_run(rid).run_id == rid  # resolves via the index
    with pytest.raises(NotFound):
        pool2.get_run("run-nope")

    done = pool2.run_to_completion(by_home[0])
    assert done.status == RUN_SUCCEEDED
    assert done.context["b"]["details"]["echo_string"] == by_home[0]
