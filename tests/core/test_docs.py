"""Docs stay truthful: every `repro.*` name resolves, every asl.md flow
runs, and every events.md Python example executes."""

import json
import os
import re

import pytest

from repro.core import asl
from repro.core.actions import ActionRegistry
from repro.core.clock import VirtualClock
from repro.core.engine import RUN_ACTIVE, FlowEngine
from repro.core.providers import EchoProvider, SleepProvider

DOCS = os.path.join(os.path.dirname(__file__), "..", "..", "docs")
DOC_FILES = [
    "ARCHITECTURE.md", "providers.md", "asl.md", "events.md", "durability.md",
    "auth.md",
]

# dotted references like `repro.core.engine.FlowEngine` (module or symbol)
_REF = re.compile(r"`(repro(?:\.[A-Za-z_][A-Za-z0-9_]*)+)`")


def _read(name):
    with open(os.path.join(DOCS, name), encoding="utf-8") as fh:
        return fh.read()


@pytest.mark.parametrize("doc", DOC_FILES)
def test_docs_exist(doc):
    assert os.path.exists(os.path.join(DOCS, doc))


@pytest.mark.parametrize("doc", DOC_FILES)
def test_every_named_symbol_resolves(doc):
    import importlib

    refs = sorted(set(_REF.findall(_read(doc))))
    assert refs, f"{doc} names no repro.* symbols"
    unresolved = []
    for ref in refs:
        parts = ref.split(".")
        obj = None
        for cut in range(len(parts), 0, -1):
            try:
                obj = importlib.import_module(".".join(parts[:cut]))
            except ImportError:
                continue
            for attr in parts[cut:]:
                obj = getattr(obj, attr, None)
                if obj is None:
                    break
            break
        if obj is None:
            unresolved.append(ref)
    assert not unresolved, f"{doc} names unresolvable symbols: {unresolved}"


def _asl_examples():
    blocks = re.findall(r"```json\n(.*?)```", _read("asl.md"), flags=re.S)
    assert len(blocks) >= 7  # one per state type plus Retry/Catch
    return blocks


def test_asl_examples_are_valid_json_and_parse():
    for block in _asl_examples():
        definition = json.loads(block)
        asl.parse(definition)  # raises FlowValidationError if stale


def _exec_python_blocks(doc: str, min_blocks: int) -> None:
    blocks = re.findall(r"```python\n(.*?)```", _read(doc), flags=re.S)
    assert len(blocks) >= min_blocks
    for i, block in enumerate(blocks):
        namespace: dict = {}
        try:
            exec(compile(block, f"{doc}[block {i}]", "exec"), namespace)
        except Exception as e:  # pragma: no cover - failure formatting
            pytest.fail(f"{doc} python block {i} failed: {e!r}")


def test_events_examples_execute():
    """Every ```python block in events.md runs (self-contained examples)."""
    # queues, router, recovery, flows, timers
    _exec_python_blocks("events.md", min_blocks=5)


def test_auth_examples_execute():
    """Every ```python block in auth.md runs (consents, expiry/refresh,
    delegation closure, coded errors from ASL, tenant admission)."""
    _exec_python_blocks("auth.md", min_blocks=5)


def test_durability_examples_execute():
    """Every ```python block in durability.md runs (the durability contract
    — record format, group commit, crash points, compaction, queue
    snapshots — stays true as the journal evolves)."""
    _exec_python_blocks("durability.md", min_blocks=5)


def test_asl_examples_run_to_completion():
    clock = VirtualClock()
    registry = ActionRegistry()
    registry.register(EchoProvider(clock=clock))
    sleep = SleepProvider(clock=clock)
    registry.register(sleep)
    engine = FlowEngine(registry, clock=clock)
    sleep.scheduler = engine.scheduler
    flow_input = {"msg": "hello", "n": 3, "cooldown": 2.0, "ok": True}
    for block in _asl_examples():
        run = engine.start_run(asl.parse(json.loads(block)), dict(flow_input))
        engine.run_to_completion(run.run_id)
        assert run.status != RUN_ACTIVE
        assert run.error is None or run.error["Error"] == "PreconditionFailed"
