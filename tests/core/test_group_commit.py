"""Group-commit durability properties (docs/durability.md contract).

The write-ahead invariant under group commit: ``Journal.append`` may return
only once the record's *batch* is durable, so **no transition is observable
before its journal record is durable** — across interleaved appends from
many worker threads and kill points between batch write, flush, and fsync:

* ``append`` returned  ⇒  the record is on disk after a crash;
* the on-disk stream is always a prefix-consistent interleaving (each
  thread's records appear in its own submission order, no holes);
* a crash poisons the journal — every later append raises, like a dead
  process — and never tears a hole mid-log;
* a torn trailing line (killed mid-write) is detected and replay stops at
  the tear instead of trusting bytes past it.

Uses the ``repro.testing`` hypothesis shim: the real hypothesis when
installed, a deterministic seeded sweep otherwise.
"""

import os
import tempfile
import threading

import pytest

from repro.core.journal import (
    GroupCommitter,
    Journal,
    JournalCrashed,
    SimulatedCrash,
    replay,
)
from repro.testing import hypothesis_shim

given, settings, st = hypothesis_shim()

PHASES = ("pre-write", "post-write", "post-flush", "post-fsync")


# ------------------------------------------------------------ GroupCommitter

def test_committer_amortizes_flushes_across_threads():
    flushed: list[list[int]] = []
    committer = GroupCommitter(lambda batch: flushed.append(list(batch)))
    n_threads, per_thread = 8, 50

    def worker(k: int) -> None:
        for i in range(per_thread):
            committer.append_and_commit(k * per_thread + i)

    threads = [threading.Thread(target=worker, args=(k,))
               for k in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    items = [x for batch in flushed for x in batch]
    assert sorted(items) == list(range(n_threads * per_thread))
    assert committer.flushes == len(flushed) <= n_threads * per_thread
    # per-thread submission order survives batching
    for k in range(n_threads):
        mine = [x for x in items if x // per_thread == k]
        assert mine == sorted(mine)


def test_committer_single_caller_pays_one_flush_no_waiting():
    flushed = []
    committer = GroupCommitter(lambda batch: flushed.append(list(batch)))
    committer.append_and_commit("only")
    assert flushed == [["only"]]


def test_committer_poisons_on_flush_failure():
    def boom(batch):
        raise OSError("disk gone")

    committer = GroupCommitter(boom, poison_on_error=True)
    with pytest.raises(OSError):
        committer.append_and_commit("x")
    with pytest.raises(JournalCrashed):
        committer.append_and_commit("y")


def test_committer_snapshot_mode_retries_after_failure():
    """Non-poisoning (queue-persistence) mode: the failed batch's callers
    see the error, the next request retries with a fresh snapshot."""
    calls = {"n": 0}

    def flaky(batch):
        calls["n"] += 1
        if calls["n"] == 1:
            raise OSError("transient")

    committer = GroupCommitter(flaky, poison_on_error=False)
    with pytest.raises(OSError):
        committer.append_and_commit("a")
    committer.append_and_commit("b")  # recovered
    assert calls["n"] == 2


# ------------------------------------------------- crash-point kill properties

def _crash_workload(n_threads: int, per_thread: int, phase: str,
                    crash_after_batches: int, workdir: str):
    """Run interleaved appends with a kill at a batch-commit boundary.

    Returns (observed, on_disk) where ``observed`` is the set of (thread,
    seq) whose ``append()`` returned, and ``on_disk`` is the post-crash
    replayed stream from a fresh journal over the same path.
    """
    path = os.path.join(workdir, f"j-{phase}-{crash_after_batches}.jsonl")
    state = {"batches": 0}
    state_lock = threading.Lock()

    def hook(p: str, batch: list[str]) -> None:
        if p != phase:
            return
        with state_lock:
            state["batches"] += 1
            if state["batches"] > crash_after_batches:
                raise SimulatedCrash(f"killed at {phase}")

    journal = Journal(path, fsync=True, fault_hook=hook)
    observed: set[tuple[int, int]] = set()
    observed_lock = threading.Lock()

    def worker(k: int) -> None:
        for i in range(per_thread):
            try:
                journal.append({"type": "t", "run_id": f"w{k}", "seq": i})
            except (SimulatedCrash, JournalCrashed, RuntimeError):
                return  # the process died under us
            with observed_lock:
                observed.add((k, i))

    threads = [threading.Thread(target=worker, args=(k,))
               for k in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    survivor = Journal(path)  # the restarted process
    on_disk = [(int(r["run_id"][1:]), r["seq"]) for r in survivor.records()]
    survivor.close()
    journal.close()
    return observed, on_disk


@settings(max_examples=20, deadline=None)
@given(
    st.integers(1, 4),
    st.integers(1, 6),
    st.sampled_from(PHASES),
    st.integers(0, 8),
)
def test_no_observation_before_durable_across_kill_points(
    n_threads, per_thread, phase, crash_after_batches
):
    with tempfile.TemporaryDirectory() as workdir:
        observed, on_disk = _crash_workload(
            n_threads, per_thread, phase, crash_after_batches, workdir
        )
    disk_set = set(on_disk)
    # 1. write-ahead: everything observed as durable IS durable
    assert observed <= disk_set, (
        f"append() returned for records lost at {phase}: "
        f"{sorted(observed - disk_set)}"
    )
    # 2. nothing fabricated: disk holds only submitted records
    assert all(0 <= k < n_threads and 0 <= i < per_thread
               for k, i in disk_set)
    # 3. prefix consistency per thread: no holes, in submission order
    for k in range(n_threads):
        mine = [seq for thread, seq in on_disk if thread == k]
        assert mine == list(range(len(mine))), (
            f"thread {k} stream has holes/reordering after {phase} kill: "
            f"{mine}"
        )


@pytest.mark.parametrize("phase", PHASES)
def test_kill_at_first_batch_boundary(phase, tmp_path):
    """Deterministic single-appender kill at every boundary: pre-write loses
    the record (never observed), post-fsync keeps it (observed)."""
    observed, on_disk = _crash_workload(1, 3, phase, 0, str(tmp_path))
    disk_set = set(on_disk)
    assert observed <= disk_set
    if phase == "pre-write":
        assert (0, 0) not in observed and (0, 0) not in disk_set
    if phase == "post-fsync":
        # the crash struck after durability; the record is on disk even
        # though the appender never saw append() return
        assert (0, 0) in disk_set


def test_poisoned_journal_refuses_all_later_appends(tmp_path):
    def hook(phase, batch):
        if phase == "post-write":
            raise SimulatedCrash("die")

    journal = Journal(str(tmp_path / "j.jsonl"), fault_hook=hook)
    with pytest.raises(SimulatedCrash):
        journal.append({"type": "t", "run_id": "a"})
    with pytest.raises(JournalCrashed):
        journal.append({"type": "t", "run_id": "b"})


# ------------------------------------------------------------ torn-tail replay

def test_torn_trailing_line_is_truncated_on_reopen(tmp_path):
    path = str(tmp_path / "j.jsonl")
    journal = Journal(path)
    journal.append({"type": "run_created", "run_id": "r1", "flow_id": "f"})
    journal.append({"type": "state_entered", "run_id": "r1", "state": "A",
                    "context": {}})
    journal.close()
    with open(path, "a", encoding="utf-8") as fh:
        fh.write('{"type":"state_exited","run_id":"r1","con')  # torn write

    survivor = Journal(path)
    records = list(survivor.records())
    assert [r["type"] for r in records] == ["run_created", "state_entered"]
    images = replay(survivor)
    assert images["r1"].current_state == "A"  # the tear never applied

    # the reopened journal sealed the tear: records appended after the
    # crash stay readable instead of gluing onto the partial line
    survivor.append({"type": "state_exited", "run_id": "r1", "next": None,
                     "context": {}})
    kinds = [r["type"] for r in Journal(path).records()]
    assert kinds == ["run_created", "state_entered", "state_exited"]


def test_serialized_baseline_mode_still_works(tmp_path):
    """``group_commit=False`` keeps the old one-fsync-per-append path (the
    benchmark baseline) semantically identical."""
    path = str(tmp_path / "j.jsonl")
    journal = Journal(path, fsync=True, group_commit=False)
    for i in range(5):
        journal.append({"type": "t", "run_id": "r", "seq": i})
    assert [r["seq"] for r in journal.records()] == list(range(5))
    journal.close()
