"""ChaosPlane: keyed determinism, injection sites, retry jitter math.

The plane's contract is that every fault decision is a pure hash of
``(seed, site, key)`` — independent of call order, thread interleaving, and
shard count.  The cross-shard-count and killed-vs-uninterrupted corollaries
live in test_failover.py; this module pins the primitive properties.
"""

import pytest

from repro.core import asl
from repro.core.actions import ActionRegistry
from repro.core.chaos import ChaosError, ChaosPlane, hash_uniform
from repro.core.clock import VirtualClock
from repro.core.engine import RUN_SUCCEEDED, FlowEngine
from repro.core.errors import FlowValidationError
from repro.core.providers import EchoProvider

# ------------------------------------------------------------- hash_uniform

def test_hash_uniform_is_pure_and_in_range():
    draws = [hash_uniform(7, "site", f"key-{i}") for i in range(500)]
    assert all(0.0 <= d < 1.0 for d in draws)
    assert draws == [hash_uniform(7, "site", f"key-{i}") for i in range(500)]
    # the draw is keyed: any component changing changes the draw
    assert hash_uniform(7, "site", "key-0") != hash_uniform(8, "site", "key-0")
    assert hash_uniform(7, "site", "key-0") != hash_uniform(7, "other", "key-0")
    # roughly uniform (coarse sanity, not a statistical test)
    assert 0.3 < sum(draws) / len(draws) < 0.7


def test_hash_uniform_key_parts_are_delimited():
    """("ab", "c") and ("a", "bc") are different keys, not one string."""
    assert hash_uniform(0, "ab", "c") != hash_uniform(0, "a", "bc")


# ------------------------------------------------------------------ invoke

def test_invoke_decisions_are_keyed_not_sequential():
    """Two planes with the same seed agree on every key, regardless of the
    order the keys are presented in."""
    a = ChaosPlane(seed=5).configure("provider.run", error_rate=0.3)
    b = ChaosPlane(seed=5).configure("provider.run", error_rate=0.3)
    keys = [f"run-{i:03d}:S:0" for i in range(200)]

    def outcome(plane, key):
        try:
            plane.invoke("provider.run", "ap://x", key)
            return "ok"
        except ChaosError:
            return "error"

    got_a = {k: outcome(a, k) for k in keys}
    got_b = {k: outcome(b, k) for k in reversed(keys)}
    assert got_a == got_b
    assert set(got_a.values()) == {"ok", "error"}  # the mix is real
    assert a.schedule() == b.schedule()


def test_unconfigured_site_is_a_no_op():
    plane = ChaosPlane(seed=1)
    plane.invoke("provider.run", "ap://x", "any-key")  # must not raise
    assert plane.timeline == []


def test_chaos_error_carries_site_and_key():
    plane = ChaosPlane(seed=0).configure("provider.run", error_rate=1.0)
    with pytest.raises(ChaosError) as err:
        plane.invoke("provider.run", "ap://x", "req-1")
    assert err.value.error_name == "ChaosError"
    assert err.value.site == "provider.run"
    assert err.value.key == "ap://x|req-1"


def test_plan_kill_validates_mode():
    plane = ChaosPlane(seed=0)
    plane.plan_kill(1, 10.0, mode="hang")
    with pytest.raises(ValueError):
        plane.plan_kill(1, 10.0, mode="detonate")


def test_journal_hook_records_without_stalling_virtual_clocks():
    clock = VirtualClock()
    plane = ChaosPlane(seed=0, clock=clock)
    plane.configure("journal.fsync", stall_rate=1.0, stall_s=3600.0)
    hook = plane.journal_hook(shard_id=2)
    hook("pre-flush", [])   # only post-flush draws
    hook("post-flush", [])
    hook("post-flush", [])
    # a wall stall under a virtual clock would hang the drain; the draw is
    # recorded (timeline stays clock-mode invariant) but nothing sleeps
    assert plane.schedule() == [
        ("journal.fsync", "shard2#1", "stall"),
        ("journal.fsync", "shard2#2", "stall"),
    ]


# ----------------------------------------------------- retry publish checks

def _retry_flow(rule):
    return {"StartAt": "E",
            "States": {"E": {"Type": "Action", "ActionUrl": "ap://echo",
                             "Parameters": {"echo_string": "x"},
                             "Retry": [rule], "End": True}}}


def test_retry_grows_max_delay_and_jitter_fields():
    flow = asl.parse(_retry_flow({
        "ErrorEquals": ["ChaosError"], "IntervalSeconds": 2.0,
        "MaxAttempts": 4, "BackoffRate": 3.0,
        "MaxDelaySeconds": 9.0, "JitterStrategy": "FULL",
    }))
    rule = flow.states["E"].retry[0]
    assert rule.max_delay_seconds == 9.0
    assert rule.jitter_strategy == "FULL"
    # both optional, with inert defaults
    plain = asl.parse(_retry_flow({"ErrorEquals": ["States.ALL"]}))
    assert plain.states["E"].retry[0].max_delay_seconds is None
    assert plain.states["E"].retry[0].jitter_strategy == "NONE"


@pytest.mark.parametrize("bad", [
    {"MaxDelaySeconds": 0},
    {"MaxDelaySeconds": -3.0},
    {"MaxDelaySeconds": "soon"},
    {"JitterStrategy": "HALF"},
    {"JitterStrategy": 1},
])
def test_retry_rejects_bad_fields_at_publish_time(bad):
    rule = {"ErrorEquals": ["States.ALL"], **bad}
    with pytest.raises(FlowValidationError):
        asl.parse(_retry_flow(rule))


# -------------------------------------------------------- engine retry math

def _engine_with_chaos(error_rate, seed=0):
    clock = VirtualClock()
    registry = ActionRegistry()
    registry.register(EchoProvider(clock=clock))
    plane = ChaosPlane(seed=seed, clock=clock)
    plane.configure("provider.run", error_rate=error_rate)
    plane.arm_providers(registry)
    return FlowEngine(registry, clock=clock), clock


def _invoke_draw(seed, run_id, attempt):
    key = f"ap://echo|{run_id}:E:{attempt}"
    return hash_uniform(seed, "provider.run", key, "error")


def test_full_jitter_delay_is_deterministic_and_capped():
    """attempt 0 draws an injected error, attempt 1 succeeds: the run
    completes at exactly interval * jitter_draw — the decorrelated-jitter
    factor is a pure hash of (run, state, attempt), replayable under a
    VirtualClock."""
    rate = 0.3
    rid = next(r for r in (f"jit-{i}" for i in range(1000))
               if _invoke_draw(0, r, 0) < rate
               and _invoke_draw(0, r, 1) >= rate)
    engine, clock = _engine_with_chaos(rate)
    flow = asl.parse(_retry_flow({
        "ErrorEquals": ["ChaosError"], "IntervalSeconds": 4.0,
        "MaxAttempts": 3, "BackoffRate": 2.0,
        "MaxDelaySeconds": 10.0, "JitterStrategy": "FULL",
    }))
    run = engine.start_run(flow, {}, run_id=rid)
    engine.drain()
    assert run.status == RUN_SUCCEEDED
    jitter = hash_uniform(0, "retry", rid, "E", 0)
    assert 0.0 < jitter < 1.0
    assert run.completion_time == pytest.approx(4.0 * jitter)


def test_max_delay_caps_the_backoff_curve():
    """Two failures with NONE jitter: delays are 4.0 then min(8.0, 5.0) —
    the cap flattens the exponential curve."""
    rate = 0.3
    rid = next(r for r in (f"cap-{i}" for i in range(5000))
               if _invoke_draw(0, r, 0) < rate
               and _invoke_draw(0, r, 1) < rate
               and _invoke_draw(0, r, 2) >= rate)
    engine, clock = _engine_with_chaos(rate)
    flow = asl.parse(_retry_flow({
        "ErrorEquals": ["ChaosError"], "IntervalSeconds": 4.0,
        "MaxAttempts": 5, "BackoffRate": 2.0,
        "MaxDelaySeconds": 5.0,
    }))
    run = engine.start_run(flow, {}, run_id=rid)
    engine.drain()
    assert run.status == RUN_SUCCEEDED
    assert run.completion_time == pytest.approx(4.0 + 5.0)
