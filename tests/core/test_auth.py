import pytest

from repro.core.auth import AuthService, Caller, principal_matches
from repro.core.errors import AuthError, ConsentRequired, NotFound


@pytest.fixture
def auth():
    a = AuthService()
    a.create_identity("alice", groups={"aps"})
    a.create_identity("bob")
    a.register_resource_server("ap.transfer")
    a.register_scope("ap.transfer", "urn:s:transfer")
    a.register_resource_server("ap.compute")
    a.register_scope("ap.compute", "urn:s:compute")
    a.register_resource_server("flow.f1")
    a.register_scope("flow.f1", "urn:s:flow.f1", ["urn:s:transfer", "urn:s:compute"])
    return a


def test_token_lifecycle(auth):
    auth.grant_consent("alice", "urn:s:transfer")
    token = auth.issue_token("alice", "urn:s:transfer")
    info = auth.introspect(token)
    assert info["active"] and info["username"] == "alice"
    assert info["scope"] == "urn:s:transfer"
    assert auth.introspect("tok-bogus") == {"active": False}
    auth.invalidate_token(token)
    assert auth.introspect(token)["active"] is False


def test_consent_required(auth):
    with pytest.raises(ConsentRequired):
        auth.issue_token("alice", "urn:s:compute")


def test_dependent_scope_closure(auth):
    closure = set(auth.dependency_closure("urn:s:flow.f1"))
    assert closure == {"urn:s:flow.f1", "urn:s:transfer", "urn:s:compute"}
    # consenting to the flow scope covers the closure (OAuth consent screen)
    auth.grant_consent("alice", "urn:s:flow.f1")
    token = auth.issue_token("alice", "urn:s:flow.f1")
    dependents = auth.get_dependent_tokens(token)
    assert set(dependents) == {"urn:s:transfer", "urn:s:compute"}
    for scope, t in dependents.items():
        assert auth.introspect(t)["scope"] == scope
        assert auth.introspect(t)["username"] == "alice"


def test_dependent_tokens_need_consent(auth):
    auth.grant_consent("bob", "urn:s:flow.f1")
    token = auth.issue_token("bob", "urn:s:flow.f1")
    auth.revoke_consent("bob", "urn:s:transfer")
    with pytest.raises(ConsentRequired):
        auth.get_dependent_tokens(token)


def test_revocation_invalidates_tokens(auth):
    auth.grant_consent("alice", "urn:s:transfer")
    token = auth.issue_token("alice", "urn:s:transfer")
    auth.revoke_consent("alice", "urn:s:transfer")
    assert auth.introspect(token)["active"] is False
    with pytest.raises(AuthError):
        auth.require(token, "urn:s:transfer")


def test_require_scope_mismatch(auth):
    auth.grant_consent("alice", "urn:s:transfer")
    token = auth.issue_token("alice", "urn:s:transfer")
    assert auth.require(token, "urn:s:transfer").username == "alice"
    with pytest.raises(AuthError):
        auth.require(token, "urn:s:compute")
    with pytest.raises(AuthError):
        auth.require(None, "urn:s:compute")


def test_unknown_entities(auth):
    with pytest.raises(NotFound):
        auth.get_identity("carol")
    with pytest.raises(NotFound):
        auth.register_scope("nope", "urn:x")
    with pytest.raises(NotFound):
        auth.register_scope("ap.transfer", "urn:y", ["urn:unregistered"])


def test_principal_matching(auth):
    alice = auth.get_identity("alice")
    assert principal_matches(alice, "user:alice")
    assert not principal_matches(alice, "user:bob")
    assert principal_matches(alice, "group:aps")
    assert principal_matches(alice, "public")
    assert principal_matches(alice, "all_authenticated_users")
    assert not principal_matches(None, "all_authenticated_users")
    assert principal_matches(None, "public")


def test_caller_wallet():
    auth = AuthService()
    ident = auth.create_identity("x")
    caller = Caller(identity=ident, tokens={"urn:a": "tok-1"})
    assert caller.token_for("urn:a") == "tok-1"
    assert caller.token_for("urn:b") is None
