import pytest

from repro.core.auth import AuthContext, AuthService, Caller, principal_matches
from repro.core.clock import VirtualClock
from repro.core.errors import AuthError, ConsentRequired, NotFound


@pytest.fixture
def auth():
    a = AuthService()
    a.create_identity("alice", groups={"aps"})
    a.create_identity("bob")
    a.register_resource_server("ap.transfer")
    a.register_scope("ap.transfer", "urn:s:transfer")
    a.register_resource_server("ap.compute")
    a.register_scope("ap.compute", "urn:s:compute")
    a.register_resource_server("flow.f1")
    a.register_scope("flow.f1", "urn:s:flow.f1", ["urn:s:transfer", "urn:s:compute"])
    return a


def test_token_lifecycle(auth):
    auth.grant_consent("alice", "urn:s:transfer")
    token = auth.issue_token("alice", "urn:s:transfer")
    info = auth.introspect(token)
    assert info["active"] and info["username"] == "alice"
    assert info["scope"] == "urn:s:transfer"
    assert auth.introspect("tok-bogus") == {"active": False}
    auth.invalidate_token(token)
    assert auth.introspect(token)["active"] is False


def test_consent_required(auth):
    with pytest.raises(ConsentRequired):
        auth.issue_token("alice", "urn:s:compute")


def test_dependent_scope_closure(auth):
    closure = set(auth.dependency_closure("urn:s:flow.f1"))
    assert closure == {"urn:s:flow.f1", "urn:s:transfer", "urn:s:compute"}
    # consenting to the flow scope covers the closure (OAuth consent screen)
    auth.grant_consent("alice", "urn:s:flow.f1")
    token = auth.issue_token("alice", "urn:s:flow.f1")
    dependents = auth.get_dependent_tokens(token)
    assert set(dependents) == {"urn:s:transfer", "urn:s:compute"}
    for scope, t in dependents.items():
        assert auth.introspect(t)["scope"] == scope
        assert auth.introspect(t)["username"] == "alice"


def test_dependent_tokens_need_consent(auth):
    auth.grant_consent("bob", "urn:s:flow.f1")
    token = auth.issue_token("bob", "urn:s:flow.f1")
    auth.revoke_consent("bob", "urn:s:transfer")
    with pytest.raises(ConsentRequired):
        auth.get_dependent_tokens(token)


def test_revocation_invalidates_tokens(auth):
    auth.grant_consent("alice", "urn:s:transfer")
    token = auth.issue_token("alice", "urn:s:transfer")
    auth.revoke_consent("alice", "urn:s:transfer")
    assert auth.introspect(token)["active"] is False
    with pytest.raises(AuthError):
        auth.require(token, "urn:s:transfer")


def test_require_scope_mismatch(auth):
    auth.grant_consent("alice", "urn:s:transfer")
    token = auth.issue_token("alice", "urn:s:transfer")
    assert auth.require(token, "urn:s:transfer").username == "alice"
    with pytest.raises(AuthError):
        auth.require(token, "urn:s:compute")
    with pytest.raises(AuthError):
        auth.require(None, "urn:s:compute")


def test_unknown_entities(auth):
    with pytest.raises(NotFound):
        auth.get_identity("carol")
    with pytest.raises(NotFound):
        auth.register_scope("nope", "urn:x")
    with pytest.raises(NotFound):
        auth.register_scope("ap.transfer", "urn:y", ["urn:unregistered"])


def test_principal_matching(auth):
    alice = auth.get_identity("alice")
    assert principal_matches(alice, "user:alice")
    assert not principal_matches(alice, "user:bob")
    assert principal_matches(alice, "group:aps")
    assert principal_matches(alice, "public")
    assert principal_matches(alice, "all_authenticated_users")
    assert not principal_matches(None, "all_authenticated_users")
    assert principal_matches(None, "public")


def test_caller_wallet():
    auth = AuthService()
    ident = auth.create_identity("x")
    caller = Caller(identity=ident, tokens={"urn:a": "tok-1"})
    assert caller.token_for("urn:a") == "tok-1"
    assert caller.token_for("urn:b") is None


# ---------------------------------------------------------------- expiry


def timed_auth(default_lifetime=None):
    clock = VirtualClock()
    a = AuthService(clock=clock, default_token_lifetime_s=default_lifetime)
    a.create_identity("alice")
    a.register_resource_server("ap.transfer")
    a.register_scope("ap.transfer", "urn:s:transfer")
    a.register_resource_server("ap.compute")
    a.register_scope("ap.compute", "urn:s:compute")
    a.register_resource_server("flow.f1")
    a.register_scope("flow.f1", "urn:s:flow.f1", ["urn:s:transfer", "urn:s:compute"])
    a.grant_consent("alice", "urn:s:flow.f1")
    return a, clock


def test_token_expiry_clock_driven():
    auth, clock = timed_auth()
    token = auth.issue_token("alice", "urn:s:transfer", lifetime_s=60.0)
    info = auth.introspect(token)
    assert info["active"] and info["exp"] == 60.0
    assert auth.token_live(token)
    clock.advance(59.9)
    assert auth.token_live(token)
    clock.advance(0.2)
    # expired: introspects inactive but keeps exp (distinguishable from
    # revocation), and require() raises the precise coded error
    info = auth.introspect(token)
    assert info["active"] is False and info["exp"] == 60.0
    assert not auth.token_live(token)
    with pytest.raises(AuthError) as exc:
        auth.require(token, "urn:s:transfer")
    assert exc.value.code == "token_expired"
    assert exc.value.as_result()["Code"] == "token_expired"


def test_default_token_lifetime():
    auth, clock = timed_auth(default_lifetime=30.0)
    token = auth.issue_token("alice", "urn:s:transfer")
    assert auth.introspect(token)["exp"] == 30.0
    forever = auth.issue_token("alice", "urn:s:transfer", lifetime_s=10_000.0)
    clock.advance(31.0)
    assert not auth.token_live(token)
    assert auth.token_live(forever)


def test_dependent_tokens_inherit_parent_expiry():
    auth, clock = timed_auth()
    parent = auth.issue_token("alice", "urn:s:flow.f1", lifetime_s=100.0)
    deps = auth.get_dependent_tokens(parent)
    for t in deps.values():
        assert auth.introspect(t)["exp"] == 100.0
    capped = auth.get_dependent_tokens(parent, lifetime_s=10.0)
    for t in capped.values():
        assert auth.introspect(t)["exp"] == 10.0
    clock.advance(101.0)
    with pytest.raises(AuthError) as exc:
        auth.get_dependent_tokens(parent)
    assert exc.value.code == "token_expired"


def test_error_codes():
    auth, clock = timed_auth()
    with pytest.raises(AuthError) as exc:
        auth.require(None, "urn:s:transfer")
    assert exc.value.code == "missing_token"
    with pytest.raises(AuthError) as exc:
        auth.require("tok-bogus", "urn:s:transfer")
    assert exc.value.code == "token_invalid"
    token = auth.issue_token("alice", "urn:s:transfer")
    with pytest.raises(AuthError) as exc:
        auth.require(token, "urn:s:compute")
    assert exc.value.code == "scope_mismatch"
    auth.revoke_consent("alice", "urn:s:transfer")
    with pytest.raises(ConsentRequired) as exc:
        auth.require(token, "urn:s:transfer")
    assert exc.value.code == "consent_required"
    assert exc.value.as_result()["Error"] == "ConsentRequired"


def test_revoke_consent_revokes_dependency_closure():
    """Regression: revoking the root scope must take down the whole
    delegation chain — dependent-scope consents AND issued tokens."""
    auth, clock = timed_auth()
    parent = auth.issue_token("alice", "urn:s:flow.f1")
    deps = auth.get_dependent_tokens(parent)
    auth.revoke_consent("alice", "urn:s:flow.f1")
    for scope in ("urn:s:flow.f1", "urn:s:transfer", "urn:s:compute"):
        assert not auth.has_consent("alice", scope)
    for scope, t in {**deps, "urn:s:flow.f1": parent}.items():
        assert auth.introspect(t)["active"] is False
        with pytest.raises(ConsentRequired):
            auth.require(t, scope)
    with pytest.raises(ConsentRequired):
        auth.issue_token("alice", "urn:s:transfer")


def test_redelegate_wallet_spans_closure():
    auth, clock = timed_auth(default_lifetime=60.0)
    wallet = auth.redelegate("alice", "urn:s:flow.f1")
    assert set(wallet) == {"urn:s:flow.f1", "urn:s:transfer", "urn:s:compute"}
    for scope, t in wallet.items():
        assert auth.require(t, scope).username == "alice"
    auth.revoke_consent("alice", "urn:s:flow.f1")
    with pytest.raises(ConsentRequired):
        auth.redelegate("alice", "urn:s:flow.f1")


def test_auth_context_refreshes_expired_token():
    """A parked run's wallet transparently re-delegates on wake: token_for
    swaps an expired token for a fresh one against the standing consent."""
    auth, clock = timed_auth()
    stale = auth.issue_token("alice", "urn:s:transfer", lifetime_s=60.0)
    ctx = AuthContext(
        identity=auth.get_identity("alice"),
        tokens={"urn:s:transfer": stale},
        auth=auth,
    )
    assert ctx.token_for("urn:s:transfer") == stale  # live: no refresh
    clock.advance(3600.0)  # parked for an hour; token long expired
    fresh = ctx.token_for("urn:s:transfer")
    assert fresh != stale and auth.token_live(fresh)
    assert ctx.tokens["urn:s:transfer"] == fresh  # wallet updated in place
    # refresh=False and no-auth-handle contexts return the stale token so
    # the downstream require() raises the precise coded error
    clock.advance(3600.0)
    assert ctx.token_for("urn:s:transfer", refresh=False) == fresh
    bare = AuthContext(identity=ctx.identity, tokens={"urn:s:transfer": fresh})
    assert bare.token_for("urn:s:transfer") == fresh
    # consent revoked: refresh impossible, stale token surfaces the error
    auth.revoke_consent("alice", "urn:s:flow.f1")
    assert ctx.token_for("urn:s:transfer") == fresh
    with pytest.raises(AuthError):
        auth.require(ctx.token_for("urn:s:transfer"), "urn:s:transfer")


# ---------------------------------------------------------------- tenants


def test_tenant_registry():
    auth = AuthService()
    auth.create_identity("alice")
    auth.create_identity("bob")
    acme = auth.register_tenant("acme", weight=4.0, rate_per_s=10.0,
                                max_concurrency=8)
    auth.register_tenant("beta")
    auth.assign_tenant("alice", "acme")
    assert auth.tenant_of(auth.get_identity("alice")) is acme
    assert auth.get_tenant("acme").weight == 4.0
    assert auth.tenant_of(auth.get_identity("bob")) is None  # unmetered
    assert auth.tenant_of(None) is None
    with pytest.raises(NotFound):
        auth.assign_tenant("alice", "nope")
    with pytest.raises(NotFound):
        auth.get_tenant("nope")
    with pytest.raises(ValueError):
        auth.register_tenant("bad", weight=0.0)


def test_auth_context_tenant_stamp():
    auth = AuthService()
    ident = auth.create_identity("alice")
    tenant = auth.register_tenant("acme", weight=2.0)
    auth.assign_tenant("alice", "acme")
    ctx = AuthContext(identity=ident, tenant=auth.tenant_of(ident))
    assert ctx.tenant is tenant and ctx.tenant_id == "acme"
    assert AuthContext(identity=ident).tenant_id is None
    # Caller stays a constructible alias for the same type
    assert Caller is AuthContext
