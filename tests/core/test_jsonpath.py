import pytest

from repro.core import jsonpath as jp
from repro.testing import hypothesis_shim

# real hypothesis when installed; deterministic seeded sweep otherwise
given, settings, st = hypothesis_shim()


def test_parse_basic():
    assert jp.parse("$") == []
    assert jp.parse("$.a.b") == ["a", "b"]
    assert jp.parse("$.a[0].b") == ["a", 0, "b"]
    assert jp.parse("$.a[-1]") == ["a", -1]
    assert jp.parse('$["key.with.dots"]') == ["key.with.dots"]


@pytest.mark.parametrize("bad", ["a.b", "$.", "$.a[", "$.a[x]", "$.a..b", "$x"])
def test_parse_rejects(bad):
    with pytest.raises(jp.JSONPathError):
        jp.parse(bad)


def test_get_and_exists():
    doc = {"a": {"b": [1, {"c": 2}]}}
    assert jp.get(doc, "$") == doc
    assert jp.get(doc, "$.a.b[1].c") == 2
    assert jp.get(doc, "$.a.b[-1].c") == 2
    assert jp.exists(doc, "$.a.b[0]")
    assert not jp.exists(doc, "$.a.z")
    assert jp.get(doc, "$.a.z", default=7) == 7
    with pytest.raises(jp.JSONPathError):
        jp.get(doc, "$.a.z")


def test_put_creates_intermediates():
    doc = {}
    jp.put(doc, "$.a.b.c", 5)
    assert doc == {"a": {"b": {"c": 5}}}
    jp.put(doc, "$.a.b.c", 6)
    assert doc["a"]["b"]["c"] == 6


def test_put_root_replaces():
    assert jp.put({"x": 1}, "$", {"y": 2}) == {"y": 2}


def test_put_list_append_and_set():
    doc = {"a": [1, 2]}
    jp.put(doc, "$.a[0]", 9)
    assert doc["a"] == [9, 2]
    jp.put(doc, "$.a[2]", 3)  # append exactly at end
    assert doc["a"] == [9, 2, 3]
    with pytest.raises(jp.JSONPathError):
        jp.put(doc, "$.a[5]", 0)


def test_is_reference():
    assert jp.is_reference("$.a")
    assert jp.is_reference("$")
    assert not jp.is_reference("plain")
    assert not jp.is_reference(42)


_keys = st.text(alphabet="abcdefgh_", min_size=1, max_size=6)


@given(st.lists(_keys, min_size=1, max_size=5), st.integers())
def test_put_get_roundtrip(path_keys, value):
    path = "$." + ".".join(path_keys)
    doc = {}
    jp.put(doc, path, value)
    assert jp.get(doc, path) == value
    assert jp.exists(doc, path)
