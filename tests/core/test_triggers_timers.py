"""Triggers and Timers services under virtual time."""

from repro.core.clock import VirtualClock
from repro.core.engine import Scheduler
from repro.core.queues import QueueService
from repro.core.timers import TimerService
from repro.core.triggers import TriggerConfig, TriggerService


def make_stack():
    clock = VirtualClock()
    scheduler = Scheduler(clock)
    queues = QueueService(clock=clock)
    return clock, scheduler, queues


def test_trigger_predicate_transform_invoke():
    clock, scheduler, queues = make_stack()
    q = queues.create_queue("instrument")
    invoked = []
    svc = TriggerService(queues, clock=clock, scheduler=scheduler)
    trig = svc.create_trigger(
        TriggerConfig(
            queue_id=q.queue_id,
            predicate='filename.endswith(".tiff") and size > 100',
            transform={"path": "filename", "nbytes": "size"},
            action_invoker=lambda body, caller: invoked.append(body) or "run-x",
        )
    )
    svc.enable(trig.trigger_id)
    queues.send(q.queue_id, {"filename": "a.tiff", "size": 500})
    queues.send(q.queue_id, {"filename": "b.h5", "size": 500})
    queues.send(q.queue_id, {"filename": "c.tiff", "size": 50})
    scheduler.drain(until=60.0)
    assert invoked == [{"path": "a.tiff", "nbytes": 500}]
    assert trig.stats["matched"] == 1
    assert trig.stats["discarded"] == 2
    assert trig.stats["invocations"] == 1
    # all events acked regardless of match
    assert queues.depth(q.queue_id) == 0


def test_trigger_adaptive_polling_backoff():
    clock, scheduler, queues = make_stack()
    q = queues.create_queue("quiet")
    svc = TriggerService(queues, clock=clock, scheduler=scheduler)
    trig = svc.create_trigger(
        TriggerConfig(
            queue_id=q.queue_id,
            predicate="True",
            poll_min_s=1.0,
            poll_max_s=16.0,
            action_invoker=lambda body, caller: "run",
        )
    )
    svc.enable(trig.trigger_id)
    scheduler.drain(until=100.0)
    quiet_polls = trig.stats["polls"]
    # with backoff 1,2,4,8,16,16,... ~ 9 polls in 100s, not 100
    assert quiet_polls <= 10
    # a message resets the interval to poll_min
    queues.send(q.queue_id, {"x": 1})
    scheduler.drain(until=120.0)
    assert trig.interval <= 2.0 or trig.stats["matched"] == 1


def test_trigger_disable_stops_polling():
    clock, scheduler, queues = make_stack()
    q = queues.create_queue("x")
    svc = TriggerService(queues, clock=clock, scheduler=scheduler)
    trig = svc.create_trigger(
        TriggerConfig(queue_id=q.queue_id, predicate="True",
                      action_invoker=lambda b, c: "r")
    )
    svc.enable(trig.trigger_id)
    scheduler.drain(until=10.0)
    svc.disable(trig.trigger_id)
    polls = trig.stats["polls"]
    queues.send(q.queue_id, {"x": 1})
    scheduler.drain(until=100.0)
    assert trig.stats["polls"] == polls
    assert trig.stats["invocations"] == 0


def test_timer_fires_on_schedule_with_count():
    clock, scheduler, _ = make_stack()
    fired = []
    svc = TimerService(
        invoker=lambda body, caller: fired.append((clock.now(), dict(body)))
        or f"run-{len(fired)}",
        clock=clock,
        scheduler=scheduler,
    )
    svc.create_timer("ckpt", interval=10.0, body={"step": "checkpoint"},
                     start=5.0, count=3)
    scheduler.drain(until=1000.0)
    assert [t for t, _ in fired] == [5.0, 15.0, 25.0]
    assert all(b == {"step": "checkpoint"} for _, b in fired)


def test_timer_end_time_expiry():
    clock, scheduler, _ = make_stack()
    fired = []
    svc = TimerService(
        invoker=lambda body, caller: fired.append(clock.now()) or "r",
        clock=clock, scheduler=scheduler,
    )
    timer = svc.create_timer("t", interval=7.0, body={}, start=0.0, end=21.0)
    scheduler.drain(until=100.0)
    assert fired == [0.0, 7.0, 14.0, 21.0]
    assert timer.active is False


def test_timer_pause_resume():
    clock, scheduler, _ = make_stack()
    fired = []
    svc = TimerService(
        invoker=lambda body, caller: fired.append(clock.now()) or "r",
        clock=clock, scheduler=scheduler,
    )
    timer = svc.create_timer("t", interval=10.0, body={}, start=0.0, count=100)
    scheduler.drain(until=25.0)
    assert len(fired) == 3  # t=0,10,20
    svc.pause(timer.timer_id)
    scheduler.drain(until=65.0)
    assert len(fired) == 3
    svc.resume(timer.timer_id)
    scheduler.drain(until=100.0)
    assert len(fired) > 3


def test_timer_persistence_recovers_missed(tmp_path):
    path = str(tmp_path / "timers.json")
    clock, scheduler, _ = make_stack()
    fired = []
    svc = TimerService(
        invoker=lambda body, caller: fired.append(clock.now()) or "r",
        clock=clock, scheduler=scheduler, persist_path=path,
    )
    svc.create_timer("t", interval=10.0, body={"k": 1}, start=0.0, count=10)
    scheduler.drain(until=15.0)
    assert len(fired) == 2  # fired at 0 and 10; "service goes down" here
    # restart later: new service, clock far beyond several missed firings
    clock2 = VirtualClock(start=55.0)
    sched2 = Scheduler(clock2)
    fired2 = []
    svc2 = TimerService(
        invoker=lambda body, caller: fired2.append(clock2.now()) or "r",
        clock=clock2, scheduler=sched2, persist_path=path,
    )
    sched2.drain(until=100.0)
    # missed firings (t=20,30,40,50) recovered promptly at restart, then the
    # schedule continues (60,70,80,90,100) => 9 more firings, 10 total fired
    timer = svc2.timers()[0]
    assert timer.fired == 10
    assert timer.active is False
    assert len(fired2) == 8


def test_timer_resume_past_deadline_fires_exactly_once():
    """Regression: resuming a paused timer whose deadline already passed
    must invoke once, not twice.

    pause() used to leave the pre-pause fire event pending in the
    scheduler; resume() scheduled a second one.  With the deadline in the
    past the ``next_due > now`` stale-wake guard stopped NEITHER — in
    real-time mode two pool threads execute the two events concurrently
    and both invoke before either advances ``next_due``.  The epoch
    carried by each fire chain kills the orphaned pre-pause event at the
    guard, independent of interleaving; this test replays the racing
    interleaving deterministically by invoking both chains' fire events
    directly, the way two executor threads would.
    """
    clock, scheduler, _ = make_stack()
    fired = []
    svc = TimerService(
        invoker=lambda body, caller: fired.append(clock.now()) or "r",
        clock=clock, scheduler=scheduler, catch_up_missed=False,
    )
    timer = svc.create_timer("t", interval=10.0, body={}, start=0.0, count=100)
    scheduler.drain(until=5.0)
    assert fired == [0.0]  # next_due=10, its fire event is pending
    stale_epoch = timer.epoch  # the epoch the pending chain carries
    svc.pause(timer.timer_id)
    # the deadline passes while paused, WITHOUT draining: the pre-pause
    # event for t=10 is still sitting in the scheduler
    clock.advance_to(35.0)
    svc.resume(timer.timer_id)
    # both events are now due in the past; dispatch them as the pool would
    svc._fire(timer.timer_id, stale_epoch)
    assert fired == [0.0], "orphaned pre-pause chain invoked after resume"
    svc._fire(timer.timer_id, timer.epoch)
    assert fired == [0.0, 35.0]
    # skip-ahead accounting (catch_up_missed=False) from the single fire
    assert timer.missed_fired == 2
    assert timer.next_due == 40.0
    # the scheduler's own copies of those events are no-ops too
    scheduler.drain(until=36.0)
    assert fired == [0.0, 35.0]
    scheduler.drain(until=41.0)
    assert fired == [0.0, 35.0, 40.0]


def test_timer_pause_resume_before_deadline_single_chain():
    """Resuming before the deadline must not double-schedule either: the
    pre-pause chain is orphaned, exactly one fire lands per due time."""
    clock, scheduler, _ = make_stack()
    fired = []
    svc = TimerService(
        invoker=lambda body, caller: fired.append(clock.now()) or "r",
        clock=clock, scheduler=scheduler,
    )
    timer = svc.create_timer("t", interval=10.0, body={}, start=0.0, count=100)
    scheduler.drain(until=5.0)
    svc.pause(timer.timer_id)
    svc.resume(timer.timer_id)  # immediately: both chains now pending
    scheduler.drain(until=25.0)
    assert fired == [0.0, 10.0, 20.0]
