"""Engine semantics under a VirtualClock: deterministic, event-driven."""

import pytest

from repro.core import asl
from repro.core.actions import ActionRegistry
from repro.core.clock import VirtualClock
from repro.core.engine import (
    RUN_CANCELLED,
    RUN_FAILED,
    RUN_SUCCEEDED,
    FlowEngine,
    PollingPolicy,
)
from repro.core.providers import EchoProvider, SleepProvider, UserSelectionProvider
from repro.core.providers.user_selection import AutoRespond


def make_engine(polling=None, **providers):
    clock = VirtualClock()
    registry = ActionRegistry()
    registry.register(EchoProvider(clock=clock))
    sleep = SleepProvider(clock=clock)
    registry.register(sleep)
    for url, p in providers.items():
        registry.register(p, url)
    engine = FlowEngine(registry, clock=clock, polling=polling)
    sleep.scheduler = engine.scheduler
    return engine, clock


def run_flow(engine, definition, flow_input):
    flow = asl.parse(definition)
    run = engine.start_run(flow, flow_input)
    return engine.run_to_completion(run.run_id)


def test_pass_choice_fail_succeed():
    definition = {
        "StartAt": "Prep",
        "States": {
            "Prep": {"Type": "Pass", "Parameters": {"double.$": "$.n"},
                     "ResultPath": "$.prep", "Next": "Branch"},
            "Branch": {
                "Type": "Choice",
                "Choices": [
                    {"Variable": "$.n", "NumericGreaterThan": 5, "Next": "Big"}
                ],
                "Default": "Small",
            },
            "Big": {"Type": "Succeed"},
            "Small": {"Type": "Fail", "Error": "TooSmall", "Cause": "n <= 5"},
        },
    }
    engine, _ = make_engine()
    run = run_flow(engine, definition, {"n": 10})
    assert run.status == RUN_SUCCEEDED
    assert run.context["prep"] == {"double": 10}

    run2 = run_flow(engine, definition, {"n": 1})
    assert run2.status == RUN_FAILED
    assert run2.error["Error"] == "TooSmall"


def test_action_result_path_and_context_flow():
    definition = {
        "StartAt": "E1",
        "States": {
            "E1": {"Type": "Action", "ActionUrl": "ap://echo",
                   "Parameters": {"echo_string.$": "$.msg"},
                   "ResultPath": "$.first", "Next": "E2"},
            "E2": {"Type": "Action", "ActionUrl": "ap://echo",
                   "Parameters": {"echo_string.$": "$.first.details.echo_string"},
                   "ResultPath": "$.second", "End": True},
        },
    }
    engine, _ = make_engine()
    run = run_flow(engine, definition, {"msg": "hello"})
    assert run.status == RUN_SUCCEEDED
    assert run.context["second"]["details"]["echo_string"] == "hello"
    assert run.context["second"]["status"] == "SUCCEEDED"


def test_sleep_action_polling_overhead_matches_paper_model():
    """Paper §6.1: first poll at 2s, doubling -> mean no-op overhead 2.88s.

    For a sleep of s seconds, completion is observed at the first poll time
    >= s, i.e. at 2*(2^k)-2... actually poll times are 2, 6, 14, 30... =
    2^(k+1)-2. Overhead = poll_time - s.
    """
    definition = {
        "StartAt": "S",
        "States": {"S": {"Type": "Action", "ActionUrl": "ap://sleep",
                          "Parameters": {"seconds.$": "$.seconds"},
                          "ResultPath": "$.r", "End": True}},
    }
    # sleep(0) is still async: observed at the first poll (t=2) — the
    # paper's 2.88s no-op overhead floor
    for seconds, expected_completion in [(0.0, 2.0), (1.0, 2.0), (3.0, 6.0),
                                         (10.0, 14.0), (100.0, 126.0)]:
        engine, clock = make_engine()
        run = run_flow(engine, definition, {"seconds": seconds})
        assert run.status == RUN_SUCCEEDED
        observed = run.completion_time - run.start_time
        assert observed == pytest.approx(expected_completion, abs=1e-6), seconds


def test_callback_mode_eliminates_polling_overhead():
    definition = {
        "StartAt": "S",
        "States": {"S": {"Type": "Action", "ActionUrl": "ap://sleep",
                          "Parameters": {"seconds": 37.0},
                          "ResultPath": "$.r", "End": True}},
    }
    engine, clock = make_engine(polling=PollingPolicy(use_callbacks=True))
    run = run_flow(engine, definition, {})
    assert run.status == RUN_SUCCEEDED
    overhead = (run.completion_time - run.start_time) - 37.0
    assert overhead == pytest.approx(0.0, abs=1e-6)
    # and far fewer polls than backoff mode would need
    assert engine.stats["polls"] <= 1


def test_wait_time_timeout_fails_state():
    definition = {
        "StartAt": "S",
        "States": {
            "S": {"Type": "Action", "ActionUrl": "ap://sleep",
                  "Parameters": {"seconds": 1000.0}, "WaitTime": 50,
                  "End": True},
        },
    }
    engine, clock = make_engine()
    run = run_flow(engine, definition, {})
    assert run.status == RUN_FAILED
    assert run.error["Error"] == "States.Timeout"
    assert clock.now() <= 60  # failed promptly after the deadline, not at 1000


def test_catch_routes_failure():
    definition = {
        "StartAt": "S",
        "States": {
            "S": {"Type": "Action", "ActionUrl": "ap://sleep",
                  "Parameters": {"seconds": 1000.0}, "WaitTime": 10,
                  "Catch": [{"ErrorEquals": ["States.Timeout"],
                              "ResultPath": "$.err", "Next": "Cleanup"}],
                  "End": True},
            "Cleanup": {"Type": "Pass", "Parameters": {"recovered": True},
                        "ResultPath": "$.cleanup", "End": True},
        },
    }
    engine, _ = make_engine()
    run = run_flow(engine, definition, {})
    assert run.status == RUN_SUCCEEDED
    assert run.context["err"]["Error"] == "States.Timeout"
    assert run.context["cleanup"] == {"recovered": True}


def test_catch_wildcard_and_action_failed():
    definition = {
        "StartAt": "Bad",
        "States": {
            "Bad": {"Type": "Action", "ActionUrl": "ap://echo",
                    # echo schema allows anything; force failure via unknown AP
                    "Parameters": {}, "Next": "Done"},
            "Done": {"Type": "Succeed"},
        },
    }
    # instead: unknown action URL should fail the run (no catch)
    definition["States"]["Bad"]["ActionUrl"] = "ap://nope"
    engine, _ = make_engine()
    run = run_flow(engine, definition, {})
    assert run.status == RUN_FAILED

    definition["States"]["Bad"]["Catch"] = [
        {"ErrorEquals": ["States.ALL"], "Next": "Done"}
    ]
    engine2, _ = make_engine()
    run2 = run_flow(engine2, definition, {})
    assert run2.status == RUN_SUCCEEDED


def test_retry_with_backoff_then_success():
    attempts = []

    class Flaky(EchoProvider):
        url = "ap://flaky"
        scope_suffix = "flaky"

        def _start(self, action, identity):
            attempts.append(self.clock.now())
            if len(attempts) < 3:
                raise RuntimeError("transient")
            super()._start(action, identity)

    engine, _ = make_engine()
    engine.registry.register(Flaky(clock=engine.clock), "ap://flaky")
    definition = {
        "StartAt": "F",
        "States": {
            "F": {"Type": "Action", "ActionUrl": "ap://flaky",
                  "Parameters": {},
                  "Retry": [{"ErrorEquals": ["States.ALL"],
                              "IntervalSeconds": 5, "MaxAttempts": 5,
                              "BackoffRate": 2.0}],
                  "End": True},
        },
    }
    run = run_flow(engine, definition, {})
    assert run.status == RUN_SUCCEEDED
    assert len(attempts) == 3
    assert engine.stats["retries"] == 2
    # retry delays: 5, then 10
    assert attempts[1] - attempts[0] == pytest.approx(5.0)
    assert attempts[2] - attempts[1] == pytest.approx(10.0)


def test_wait_state_advances_clock():
    definition = {
        "StartAt": "W",
        "States": {
            "W": {"Type": "Wait", "SecondsPath": "$.pause", "Next": "Done"},
            "Done": {"Type": "Succeed"},
        },
    }
    engine, clock = make_engine()
    run = run_flow(engine, definition, {"pause": 42})
    assert run.status == RUN_SUCCEEDED
    assert clock.now() == pytest.approx(42.0)


def test_parallel_branches_join_and_fail():
    definition = {
        "StartAt": "P",
        "States": {
            "P": {
                "Type": "Parallel",
                "Branches": [
                    {"StartAt": "A", "States": {
                        "A": {"Type": "Action", "ActionUrl": "ap://sleep",
                              "Parameters": {"seconds": 3.0}, "End": True}}},
                    {"StartAt": "B", "States": {
                        "B": {"Type": "Pass", "Parameters": {"b": 1},
                              "ResultPath": "$.out", "End": True}}},
                ],
                "ResultPath": "$.joined",
                "Next": "Done",
            },
            "Done": {"Type": "Succeed"},
        },
    }
    engine, _ = make_engine()
    run = run_flow(engine, definition, {"seed": 1})
    assert run.status == RUN_SUCCEEDED
    assert len(run.context["joined"]) == 2
    assert run.context["joined"][1]["out"] == {"b": 1}

    # failing branch fails the parallel state
    definition["States"]["P"]["Branches"][1]["States"]["B"] = {
        "Type": "Fail", "Error": "Boom", "Cause": "branch failure"
    }
    engine2, _ = make_engine()
    run2 = run_flow(engine2, definition, {})
    assert run2.status == RUN_FAILED
    assert run2.error["Error"] == "States.BranchFailed"


def test_cancel_run():
    definition = {
        "StartAt": "S",
        "States": {"S": {"Type": "Action", "ActionUrl": "ap://sleep",
                          "Parameters": {"seconds": 500.0}, "End": True}},
    }
    engine, clock = make_engine()
    flow = asl.parse(definition)
    run = engine.start_run(flow, {})
    engine.scheduler.drain(until=5.0)
    engine.cancel_run(run.run_id)
    engine.run_to_completion(run.run_id)
    assert run.status == RUN_CANCELLED


def test_user_selection_blocks_until_response():
    clock = VirtualClock()
    sel = UserSelectionProvider(clock=clock)
    engine, _ = make_engine(**{"ap://user_selection": sel})
    sel.clock = engine.clock
    definition = {
        "StartAt": "Review",
        "States": {"Review": {"Type": "Action", "ActionUrl": "ap://user_selection",
                               "Parameters": {"options": ["approve", "reject"]},
                               "ResultPath": "$.review", "End": True}},
    }
    flow = asl.parse(definition)
    run = engine.start_run(flow, {})
    engine.run_to_completion(run.run_id, until=3600.0)
    assert run.status == "ACTIVE"  # stalled awaiting human input
    [action_id] = sel.pending()
    sel.respond(action_id, "approve", responder="curator")
    engine.run_to_completion(run.run_id)
    assert run.status == RUN_SUCCEEDED
    assert run.context["review"]["details"]["selection"] == "approve"


def test_auto_respond_selection():
    clock = VirtualClock()
    sel = UserSelectionProvider(clock=clock, auto_respond=AutoRespond(30.0, 1))
    engine, _ = make_engine(**{"ap://user_selection": sel})
    sel.clock = engine.clock
    definition = {
        "StartAt": "Review",
        "States": {"Review": {"Type": "Action", "ActionUrl": "ap://user_selection",
                               "Parameters": {"options": ["approve", "reject"]},
                               "ResultPath": "$.review", "End": True}},
    }
    flow = asl.parse(definition)
    run = engine.start_run(flow, {})
    engine.run_to_completion(run.run_id)
    assert run.status == RUN_SUCCEEDED
    assert run.context["review"]["details"]["selection"] == "reject"


def test_events_log_records_lifecycle():
    definition = {
        "StartAt": "E",
        "States": {"E": {"Type": "Action", "ActionUrl": "ap://echo",
                          "Parameters": {"echo_string": "x"}, "End": True}},
    }
    engine, _ = make_engine()
    run = run_flow(engine, definition, {})
    codes = [e["code"] for e in run.events]
    assert codes[0] == "FlowStarted"
    assert "StateEntered" in codes and "ActionCompleted" in codes
    assert codes[-1] == "FlowCompleted"


# ---------------------------------------------------------- Wait edge cases

def _wait_path_flow(next_state="Done"):
    return {
        "StartAt": "W",
        "States": {
            "W": {"Type": "Wait", "SecondsPath": "$.pause", "Next": next_state},
            "Done": {"Type": "Succeed"},
        },
    }


def test_wait_seconds_path_zero_fires_immediately():
    engine, clock = make_engine()
    run = run_flow(engine, _wait_path_flow(), {"pause": 0})
    assert run.status == RUN_SUCCEEDED
    assert clock.now() == pytest.approx(0.0)


def test_wait_seconds_path_float():
    engine, clock = make_engine()
    run = run_flow(engine, _wait_path_flow(), {"pause": 0.25})
    assert run.status == RUN_SUCCEEDED
    assert clock.now() == pytest.approx(0.25)


def test_wait_seconds_path_negative_fails_at_run_time():
    """A negative SecondsPath value cannot be caught at publish time (the
    context is unknown); it fails the *state* as States.Runtime."""
    engine, _ = make_engine()
    run = run_flow(engine, _wait_path_flow(), {"pause": -5})
    assert run.status == RUN_FAILED
    assert run.error["Error"] == "States.Runtime"
    assert "negative" in run.error["Cause"]


def test_wait_seconds_path_non_numeric_fails_at_run_time():
    engine, _ = make_engine()
    for bad in ("soon", None, True, [3]):
        run = run_flow(engine, _wait_path_flow(), {"pause": bad})
        assert run.status == RUN_FAILED
        assert run.error["Error"] == "States.Runtime"
        assert "not a number" in run.error["Cause"]


def test_wait_seconds_path_failure_is_catchable():
    """The run-time validation failure is an ordinary state failure: Catch
    routes it like any other States.Runtime."""
    definition = {
        "StartAt": "W",
        "States": {
            "W": {"Type": "Wait", "SecondsPath": "$.pause",
                  "Catch": [{"ErrorEquals": ["States.Runtime"],
                             "ResultPath": "$.err", "Next": "Fallback"}],
                  "Next": "Done"},
            "Fallback": {"Type": "Pass", "Result": {"handled": True},
                         "ResultPath": "$.fb", "End": True},
            "Done": {"Type": "Succeed"},
        },
    }
    engine, _ = make_engine()
    run = run_flow(engine, definition, {"pause": "not-a-number"})
    assert run.status == RUN_SUCCEEDED
    assert run.context["fb"] == {"handled": True}
    assert run.context["err"]["Error"] == "States.Runtime"


def test_wait_literal_negative_seconds_rejected_at_publish_time():
    """A literal negative Seconds is statically wrong: it must fail
    asl.parse (publish time), never reach a run."""
    from repro.core.errors import FlowValidationError

    definition = {
        "StartAt": "W",
        "States": {"W": {"Type": "Wait", "Seconds": -1, "Next": "Done"},
                   "Done": {"Type": "Succeed"}},
    }
    with pytest.raises(FlowValidationError, match=">= 0"):
        asl.parse(definition)


def test_wait_literal_boolean_seconds_rejected_at_publish_time():
    from repro.core.errors import FlowValidationError

    definition = {
        "StartAt": "W",
        "States": {"W": {"Type": "Wait", "Seconds": True, "Next": "Done"},
                   "Done": {"Type": "Succeed"}},
    }
    with pytest.raises(FlowValidationError, match="boolean"):
        asl.parse(definition)


def test_wait_fires_across_checkpoint_compaction_boundary(tmp_path):
    """A Wait parked before a compaction still fires correctly after it:
    compaction swaps the journal generation (invalidating any byte-offset
    fast path into the old segment), so the wake must fall back to segment
    replay and still complete the run — for both a resident wait and a
    passivated one."""
    from repro.core.journal import Journal

    definition = {
        "StartAt": "W",
        "States": {
            "W": {"Type": "Wait", "Seconds": 100.0, "Next": "Done"},
            "Done": {"Type": "Pass", "Result": {"ok": 1},
                     "ResultPath": "$.done", "End": True},
        },
    }
    for passivate_after in (None, 10.0):
        clock = VirtualClock()
        registry = ActionRegistry()
        registry.register(EchoProvider(clock=clock))
        journal = Journal(str(tmp_path / f"j-{passivate_after}.jsonl"))
        engine = FlowEngine(registry, clock=clock, journal=journal,
                            passivate_after=passivate_after)
        flow = asl.parse(definition)
        run = engine.start_run(flow, {"x": 1}, flow_id="f")
        engine.scheduler.drain(until=50.0)  # parked mid-wait
        if passivate_after is not None:
            assert run.run_id in engine.dormant
        journal.compact()  # generation swap exactly at the boundary
        engine.scheduler.drain(until=200.0)  # the wake fires post-compaction
        live = engine.get_run(run.run_id)
        assert live.status == RUN_SUCCEEDED
        assert live.context["done"] == {"ok": 1}
        assert live.context["x"] == 1
