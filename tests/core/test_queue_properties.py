"""Property-based QueueService tests (paper §5.4 delivery semantics).

Random interleavings of send / deferred send / receive / ack / clock advance
/ **crash** (service restart over the JSONL persistence file) must preserve:

* **at-least-once** — every sent message is eventually delivered;
* **no post-ack redelivery** — an acknowledged message never reappears;
* **in-order receivability** — first deliveries happen in send order, and a
  deferred message gates everything sent after it;
* **deferred delivery** — no message is delivered before its delivery time;
* **visibility-timeout redelivery** — unacked messages reappear once their
  receipt expires (including receipts orphaned by a crash).

Uses the ``repro.testing`` hypothesis shim: the real hypothesis when
installed, a deterministic seeded sweep otherwise.
"""

import pytest

from repro.core.clock import VirtualClock
from repro.core.errors import QueueInvariantError
from repro.core.queues import QueueService
from repro.testing import hypothesis_shim

given, settings, st = hypothesis_shim()

VISIBILITY = 20.0

OPS = st.lists(
    st.one_of(
        st.tuples(st.just("send"), st.just(0)),
        st.tuples(st.just("send_deferred"), st.integers(1, 30)),
        st.tuples(st.just("receive"), st.integers(1, 4)),
        st.tuples(st.just("ack"), st.just(0)),
        st.tuples(st.just("advance"), st.integers(1, 25)),
        st.tuples(st.just("crash"), st.just(0)),
    ),
    max_size=70,
)


class _Model:
    """Reference bookkeeping for the properties under test."""

    def __init__(self):
        self.sent: list[int] = []            # message payload numbers, in order
        self.deliver_after: dict[int, float] = {}
        self.acked: set[int] = set()
        self.seen: set[int] = set()
        self.first_delivery_order: list[int] = []
        self.outstanding: list[tuple[str, int]] = []  # (receipt, n), FIFO

    def on_receive(self, svc, queue_id, clock, batch):
        for m in svc.receive(queue_id, max_messages=batch):
            n = m["body"]["n"]
            assert n not in self.acked, "acked message redelivered"
            assert clock.now() >= self.deliver_after[n], (
                "message delivered before its deferred delivery time"
            )
            if n not in self.seen:
                self.seen.add(n)
                self.first_delivery_order.append(n)
            self.outstanding.append((m["receipt"], n))

    def on_ack(self, svc, queue_id):
        if not self.outstanding:
            return
        receipt, n = self.outstanding.pop(0)
        try:
            svc.ack(queue_id, receipt)
            self.acked.add(n)
        except QueueInvariantError:
            pass  # expired or crash-orphaned receipt; redelivery covers it


def _run_ops(ops, persist_path=None):
    clock = VirtualClock()
    svc = QueueService(clock=clock, persist_path=persist_path)
    q = svc.create_queue("prop", visibility_timeout=VISIBILITY)
    model = _Model()
    for op, arg in ops:
        if op == "send":
            n = len(model.sent)
            svc.send(q.queue_id, {"n": n})
            model.sent.append(n)
            model.deliver_after[n] = clock.now()
        elif op == "send_deferred":
            n = len(model.sent)
            svc.send(q.queue_id, {"n": n}, delay=float(arg))
            model.sent.append(n)
            model.deliver_after[n] = clock.now() + float(arg)
        elif op == "receive":
            model.on_receive(svc, q.queue_id, clock, arg)
        elif op == "ack":
            model.on_ack(svc, q.queue_id)
        elif op == "advance":
            clock.advance(float(arg))
        elif op == "crash" and persist_path is not None:
            # restart: a fresh service over the same file; in-flight receipts
            # are lost, so unacked messages must become redeliverable
            svc = QueueService(clock=clock, persist_path=persist_path)
            model.outstanding.clear()

    # drain: everything unacked must still be deliverable (at-least-once),
    # with enough clock advance to expire every receipt and deferral
    for _ in range(len(model.sent) + 8):
        clock.advance(VISIBILITY + 31.0)
        got = svc.receive(q.queue_id, max_messages=10)
        for m in got:
            n = m["body"]["n"]
            assert n not in model.acked, "acked message redelivered in drain"
            if n not in model.seen:
                model.seen.add(n)
                model.first_delivery_order.append(n)
            svc.ack(q.queue_id, m["receipt"])
            model.acked.add(n)
        if not got and svc.depth(q.queue_id) == 0:
            break

    assert model.seen == set(model.sent), "every sent message must be delivered"
    assert svc.depth(q.queue_id) == 0, "drain must empty the queue"
    # in-order receivability: deferred gating keeps first deliveries in
    # send order (a deferred message blocks everything sent after it)
    assert model.first_delivery_order == sorted(model.first_delivery_order)


@settings(max_examples=40, deadline=None)
@given(ops=OPS)
def test_delivery_properties_in_memory(ops):
    _run_ops([(op, arg) for op, arg in ops if op != "crash"])


@settings(max_examples=40, deadline=None)
@given(ops=OPS)
def test_delivery_properties_survive_crashes(ops):
    import os
    import tempfile

    with tempfile.TemporaryDirectory() as d:
        _run_ops(ops, persist_path=os.path.join(d, "queues.json"))


def test_visibility_timeout_redelivers_after_crash(tmp_path):
    """Receipts orphaned by a crash cannot ack; the message redelivers."""
    path = str(tmp_path / "queues.json")
    clock = VirtualClock()
    svc = QueueService(clock=clock, persist_path=path)
    q = svc.create_queue("crashy", visibility_timeout=VISIBILITY)
    svc.send(q.queue_id, {"n": 0})
    [m] = svc.receive(q.queue_id)

    svc2 = QueueService(clock=clock, persist_path=path)
    with pytest.raises(QueueInvariantError):
        svc2.ack(q.queue_id, m["receipt"])
    [m2] = svc2.receive(q.queue_id)  # immediately redeliverable: receipt died
    assert m2["body"] == {"n": 0}
    assert m2["receive_count"] >= 2  # receive_count survived persistence
    svc2.ack(q.queue_id, m2["receipt"])
    assert svc2.depth(q.queue_id) == 0
