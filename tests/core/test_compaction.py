"""Checkpoint compaction: bounded recovery + crash-equivalence.

The compaction invariant (docs/ARCHITECTURE.md invariant 6): a checkpoint
record is *defined* as the replay of the history it replaces, so recovery
from a compacted segment must be indistinguishable from recovery from the
full history — for runs, triggers (lifecycle + ack-progress), and service
counters — and crash-point injection at every group-commit batch boundary
must recover to the same terminal states as an uninterrupted run.
"""

import os

import pytest

from repro.core import asl
from repro.core.actions import ActionRegistry
from repro.core.clock import VirtualClock
from repro.core.engine import RUN_ACTIVE, RUN_SUCCEEDED, FlowEngine
from repro.core.flows_service import FlowsService
from repro.core.journal import (
    Journal,
    JournalCrashed,
    SimulatedCrash,
    replay,
    replay_counters,
    segment_path,
)
from repro.core.providers import EchoProvider, SleepProvider
from repro.core.queues import QueueService
from repro.core.shard_pool import EngineShardPool

CHAIN = {
    "StartAt": "A",
    "States": {
        "A": {"Type": "Action", "ActionUrl": "ap://echo",
              "Parameters": {"echo_string.$": "$.msg"},
              "ResultPath": "$.a", "Next": "Pause"},
        "Pause": {"Type": "Action", "ActionUrl": "ap://sleep",
                  "Parameters": {"seconds": 50.0},
                  "ResultPath": "$.pause", "Next": "B"},
        "B": {"Type": "Action", "ActionUrl": "ap://echo",
              "Parameters": {"echo_string.$": "$.a.details.echo_string"},
              "ResultPath": "$.b", "End": True},
    },
}

PASS_FLOW = {
    "StartAt": "Noop",
    "States": {"Noop": {"Type": "Pass", "End": True}},
}


def make_engine(journal: Journal):
    clock = VirtualClock()
    registry = ActionRegistry()
    registry.register(EchoProvider(clock=clock))
    registry.register(SleepProvider(clock=clock))
    return FlowEngine(registry, clock=clock, journal=journal)


def _grow_history(engine, completed: int, live: int):
    """``completed`` finished pass-runs + ``live`` chains parked in Pause."""
    pass_flow = asl.parse(PASS_FLOW)
    chain = asl.parse(CHAIN)
    for i in range(completed):
        run = engine.start_run(pass_flow, {}, flow_id="p",
                               run_id=f"run-done{i:04d}")
        engine.run_to_completion(run.run_id)
    for i in range(live):
        engine.start_run(chain, {"msg": f"m{i}"}, flow_id="f",
                         run_id=f"run-live{i:04d}")
    engine.scheduler.drain(until=10.0)


# ------------------------------------------------------------- equivalence

def test_compacted_recovery_equals_full_history_recovery(tmp_path):
    full = str(tmp_path / "full.jsonl")
    compacted = str(tmp_path / "compacted.jsonl")
    for path in (full, compacted):
        engine = make_engine(Journal(path))
        _grow_history(engine, completed=25, live=3)

    summary = Journal(compacted).compact()
    assert summary["records_after"] == 1 < summary["records_before"]
    assert summary["live_runs"] == 3

    outcomes = {}
    for path in (full, compacted):
        engine = make_engine(Journal(path))
        resumed = engine.recover(
            {"f": asl.parse(CHAIN), "p": asl.parse(PASS_FLOW)}
        )
        engine.scheduler.drain()
        outcomes[path] = {
            run.run_id: (run.status, run.context["b"]["details"])
            for run in resumed
        }
    assert outcomes[full] == outcomes[compacted]
    assert len(outcomes[full]) == 3
    assert all(s == RUN_SUCCEEDED for s, _ in outcomes[full].values())


def test_checkpoint_drops_terminal_runs_and_keeps_tail(tmp_path):
    path = str(tmp_path / "j.jsonl")
    engine = make_engine(Journal(path))
    _grow_history(engine, completed=40, live=2)
    engine.compact()
    # tail records appended AFTER the checkpoint apply on top of it
    engine.journal.append(
        {"type": "run_cancelled", "run_id": "run-live0000", "t": 11.0}
    )
    images = replay(Journal(path))
    assert set(images) == {"run-live0000", "run-live0001"}
    assert images["run-live0000"].status == "CANCELLED"
    assert images["run-live0001"].status == RUN_ACTIVE


def test_checkpoint_counters_restore_into_stats(tmp_path):
    path = str(tmp_path / "j.jsonl")
    engine = make_engine(Journal(path))
    _grow_history(engine, completed=10, live=1)
    engine.compact()
    counters, generation = replay_counters(Journal(path))
    assert generation == 1
    assert counters["runs_started"] == 11
    assert counters["runs_succeeded"] == 10

    engine2 = make_engine(Journal(path))
    engine2.recover({"f": asl.parse(CHAIN), "p": asl.parse(PASS_FLOW)})
    assert engine2.stats["runs_started"] == 11
    assert engine2.stats["runs_succeeded"] == 10


def test_repeated_compaction_bumps_generation(tmp_path):
    path = str(tmp_path / "j.jsonl")
    journal = Journal(path)
    journal.append({"type": "run_created", "run_id": "r", "flow_id": "f"})
    assert journal.compact()["generation"] == 1
    journal.append({"type": "state_entered", "run_id": "r", "state": "A",
                    "context": {}})
    assert journal.compact()["generation"] == 2
    # a fresh journal over the segment learns the generation from the file
    assert Journal(path).generation == 2


def test_auto_compaction_bounds_segment_length(tmp_path):
    path = str(tmp_path / "j.jsonl")
    engine = make_engine(Journal(path, compact_every=30))
    _grow_history(engine, completed=50, live=2)  # ~200 records uncompacted
    assert engine.journal.generation >= 1
    tail = sum(1 for _ in engine.journal.records())
    assert tail <= 31 + 1  # one checkpoint + a bounded tail
    engine2 = make_engine(Journal(path))
    resumed = engine2.recover({"f": asl.parse(CHAIN), "p": asl.parse(PASS_FLOW)})
    engine2.scheduler.drain()
    assert sorted(r.run_id for r in resumed) == ["run-live0000", "run-live0001"]
    assert all(r.status == RUN_SUCCEEDED for r in resumed)


def test_in_memory_journal_compacts_too():
    journal = Journal()
    engine = make_engine(journal)
    _grow_history(engine, completed=15, live=1)
    summary = engine.compact()
    assert summary["records_after"] == 1
    assert summary["live_runs"] == 1
    assert len(replay(journal)) == 1


# -------------------------------------------------- triggers survive compaction

def test_trigger_state_survives_compaction(tmp_path):
    """Trigger lifecycle + ack-progress collapse into the checkpoint and
    recover identically through FlowsService.recover_triggers."""
    path = str(tmp_path / "journal.jsonl")
    # the Queues service survives the Flows "crash" (paper: separate service)
    clock = VirtualClock()
    queues = QueueService(clock=clock)

    def build(shards=2):
        registry = ActionRegistry()
        registry.register(EchoProvider(clock=clock))
        registry.register(SleepProvider(clock=clock))
        return FlowsService(registry, clock=clock, shards=shards,
                            journal_path=path, queues=queues)

    flows = build()
    flows.publish_flow(PASS_FLOW, title="sink", flow_id="sink")
    q = queues.create_queue("events")
    trig = flows.create_trigger(q.queue_id, "kind == 'go'", "sink",
                                trigger_id="trig-compact")
    flows.enable_trigger(trig.trigger_id)
    for i in range(4):
        queues.send(q.queue_id, {"kind": "go", "i": i})
    flows.engine.drain()
    assert flows.trigger_status("trig-compact")["stats"]["invocations"] == 4

    summaries = flows.compact()
    assert sum(s["triggers"] for s in summaries) == 1
    assert all(s["records_after"] == 1 for s in summaries)

    # restart the Flows side over the compacted segments
    flows2 = build()
    flows2.publish_flow(PASS_FLOW, title="sink", flow_id="sink")
    recovered = flows2.recover_triggers()
    assert [t.trigger_id for t in recovered] == ["trig-compact"]
    assert recovered[0].enabled
    assert recovered[0].stats["invocations"] == 4


# ------------------------------------- crash injection at batch boundaries

#: CI's durability job injects the shard count (ci.yml: REPRO_TEST_SHARDS=4)
SHARDS = int(os.environ.get("REPRO_TEST_SHARDS", "4"))


def _shard_journals(path, shards=None, fault_hook=None, **kwargs):
    shards = SHARDS if shards is None else shards
    return [
        Journal(segment_path(path, i, shards), fault_hook=fault_hook, **kwargs)
        for i in range(shards)
    ]


def make_pool(journals):
    clock = VirtualClock()
    registry = ActionRegistry()
    registry.register(EchoProvider(clock=clock))
    registry.register(SleepProvider(clock=clock))
    pool = EngineShardPool(
        registry, num_shards=len(journals), clock=clock, journals=journals
    )
    return pool, clock


def _reference_outcomes():
    pool, _ = make_pool([Journal() for _ in range(SHARDS)])
    chain = asl.parse(CHAIN)
    for i in range(12):
        pool.start_run(chain, {"msg": f"m{i}"}, flow_id="flow",
                       run_id=f"run-{i:04d}")
    pool.drain()
    return {
        rid: (run.status, run.context["b"]["details"])
        for rid, run in pool.runs.items()
    }


def _crash_points():
    """Every (phase, batch ordinal) boundary the 12-run workload commits."""
    for phase in ("pre-write", "post-write", "post-fsync"):
        for crash_after in (0, 1, 3, 7, 15, 31, 63):
            yield phase, crash_after


@pytest.mark.parametrize("phase,crash_after", list(_crash_points()))
def test_crash_at_batch_boundary_recovers_to_reference(
    phase, crash_after, tmp_path
):
    """Kill a 4-shard pool at a group-commit batch boundary; recovery must
    reach the reference terminal states for every journaled run."""
    reference = _reference_outcomes()
    path = str(tmp_path / "journal.jsonl")
    state = {"batches": 0}

    def hook(p: str, batch: list) -> None:
        if p != phase:
            return
        state["batches"] += 1
        if state["batches"] > crash_after:
            raise SimulatedCrash(f"killed at {phase} #{state['batches']}")

    pool1, _ = make_pool(_shard_journals(path, 4, fault_hook=hook))
    chain = asl.parse(CHAIN)
    journaled: list[str] = []
    crashed = False
    try:
        for i in range(12):
            pool1.start_run(chain, {"msg": f"m{i}"}, flow_id="flow",
                            run_id=f"run-{i:04d}")
            journaled.append(f"run-{i:04d}")
        pool1.drain()
    except (SimulatedCrash, JournalCrashed):
        crashed = True

    # the "restarted process": fresh pool + providers over the segments
    journals = _shard_journals(path)
    # snapshot what the crash left durable BEFORE recovery resumes anything
    images = {}
    for journal in journals:
        images.update(replay(journal))
    pool2, _ = make_pool(journals)
    resumed = pool2.recover({"flow": chain})
    pool2.drain()

    # every run whose run_created reached the journal recovers to the
    # reference terminal state; runs whose start_run crashed pre-journal
    # were never admitted (the caller saw the crash) and may be absent
    recovered = {r.run_id: r for r in pool2.runs.values()}
    assert set(r.run_id for r in resumed) == {
        rid for rid, image in images.items() if image.status == RUN_ACTIVE
    }
    for rid, image in images.items():
        ref_status, ref_details = reference[rid]
        if image.status == RUN_ACTIVE:
            # unfinished at the crash: recovery must finish it
            run = recovered[rid]
            assert run.status == ref_status == RUN_SUCCEEDED, (
                f"{rid} diverged after {phase} crash: {run.status}"
            )
            assert run.context["b"]["details"] == ref_details
        else:
            # journaled terminal before the crash: the durable context
            # already matches the reference outcome
            assert image.status == ref_status == RUN_SUCCEEDED
            assert image.context["b"]["details"] == ref_details
    if not crashed:
        # crash point beyond the workload's batch count: everything ran
        assert set(journaled) == set(images)


def test_crash_then_compact_then_crash_again(tmp_path):
    """Compaction between two crashes preserves the recovery contract."""
    reference = _reference_outcomes()
    path = str(tmp_path / "journal.jsonl")
    chain = asl.parse(CHAIN)

    pool1, _ = make_pool(_shard_journals(path))
    for i in range(12):
        pool1.start_run(chain, {"msg": f"m{i}"}, flow_id="flow",
                        run_id=f"run-{i:04d}")
    pool1.drain(until=10.0)  # crash no.1: all runs parked in Pause

    pool2, _ = make_pool(_shard_journals(path))
    pool2.recover({"flow": chain})
    pool2.compact()
    pool2.drain(until=20.0)  # crash no.2: still mid-flight, post-checkpoint

    pool3, _ = make_pool(_shard_journals(path))
    resumed = pool3.recover({"flow": chain})
    pool3.drain()
    assert sorted(r.run_id for r in resumed) == sorted(reference)
    for run in resumed:
        ref_status, ref_details = reference[run.run_id]
        assert run.status == ref_status == RUN_SUCCEEDED
        assert run.context["b"]["details"] == ref_details
