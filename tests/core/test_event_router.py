"""EventRouter: push delivery, shared dispatch, durability, determinism.

Extends the crash-recovery patterns of test_recovery.py / test_shard_pool.py
to the event fabric: triggers are journaled like runs, hash-owned by shards,
and recovered per segment.
"""

import pytest

from repro.core.actions import ActionRegistry
from repro.core.clock import VirtualClock
from repro.core.engine import Scheduler
from repro.core.errors import NotFound
from repro.core.flows_service import FlowsService
from repro.core.journal import Journal, replay_triggers
from repro.core.providers import EchoProvider
from repro.core.queues import QueueService
from repro.core.triggers import EventRouter, TriggerConfig

ECHO_FLOW = {
    "StartAt": "E",
    "States": {
        "E": {"Type": "Action", "ActionUrl": "ap://echo",
              "Parameters": {"echo_string.$": "$.msg"}, "End": True}
    },
}


def make_router(journal=None):
    clock = VirtualClock()
    scheduler = Scheduler(clock)
    queues = QueueService(clock=clock)
    router = EventRouter(queues, clock=clock, scheduler=scheduler,
                         journal=journal)
    return router, queues, scheduler, clock


def make_flows(shards=1, journal_path=None, queues=None, clock=None):
    clock = clock or VirtualClock()
    registry = ActionRegistry()
    registry.register(EchoProvider(clock=clock))
    queues = queues if queues is not None else QueueService(clock=clock)
    flows = FlowsService(registry, clock=clock, shards=shards,
                         journal_path=journal_path, queues=queues)
    return flows, queues, clock


# ------------------------------------------------------------------ push-first

def test_push_wakes_immediately_no_poll_wait():
    """send() dispatches at the send's virtual time, not a poll interval."""
    router, queues, scheduler, clock = make_router()
    q = queues.create_queue("hot")
    invoked = []
    trig = router.create_trigger(TriggerConfig(
        queue_id=q.queue_id, predicate="True",
        poll_min_s=500.0, poll_max_s=500.0,  # polling alone would take 500 s
        action_invoker=lambda body, c: invoked.append((clock.now(), body)) or "r",
    ))
    router.enable(trig.trigger_id)
    scheduler.drain(until=1.0)

    def send_at(t, n):
        scheduler.call_at(t, lambda: queues.send(q.queue_id, {"n": n}))

    send_at(10.0, 0)
    send_at(33.5, 1)
    scheduler.drain(until=100.0)
    assert [t for t, _ in invoked] == [10.0, 33.5]
    assert queues.depth(q.queue_id) == 0


def test_deferred_send_dispatches_at_delivery_time():
    router, queues, scheduler, clock = make_router()
    q = queues.create_queue("later")
    invoked = []
    trig = router.create_trigger(TriggerConfig(
        queue_id=q.queue_id, predicate="True",
        action_invoker=lambda body, c: invoked.append(clock.now()) or "r",
    ))
    router.enable(trig.trigger_id)
    queues.send(q.queue_id, {"n": 1}, delay=42.0)
    scheduler.drain(until=1000.0)
    assert invoked == [42.0]


def test_deferred_head_does_not_starve_later_sends():
    """FIFO: a deferred head blocks later messages, but the router wakes at
    the head's delivery time and drains everything in order."""
    router, queues, scheduler, clock = make_router()
    q = queues.create_queue("fifo")
    invoked = []
    trig = router.create_trigger(TriggerConfig(
        queue_id=q.queue_id, predicate="True", transform={"n": "n"},
        action_invoker=lambda body, c: invoked.append((clock.now(), body["n"]))
        or "r",
    ))
    router.enable(trig.trigger_id)
    queues.send(q.queue_id, {"n": 1}, delay=50.0)
    queues.send(q.queue_id, {"n": 2})
    scheduler.drain(until=1000.0)
    assert [n for _, n in invoked] == [1, 2]  # send order preserved
    assert invoked[0][0] == 50.0


# --------------------------------------------------------- shared batch pass

def test_one_receive_serves_all_triggers_on_a_queue():
    """All predicates subscribed to a queue are evaluated in one pass: one
    receive call per batch, every matching trigger fires on the same event."""
    router, queues, scheduler, clock = make_router()
    q = queues.create_queue("shared")
    hits = {"tiff": [], "big": [], "never": []}
    for key, pred in [("tiff", 'name.endswith(".tiff")'),
                      ("big", "size > 100"),
                      ("never", "size > 10**6")]:
        trig = router.create_trigger(TriggerConfig(
            queue_id=q.queue_id, predicate=pred,
            action_invoker=lambda body, c, k=key: hits[k].append(body) or "r",
            transform={"name": "name", "size": "size"},
        ))
        router.enable(trig.trigger_id)
    before = queues.stats["receives"]
    queues.send(q.queue_id, {"name": "a.tiff", "size": 500})
    queues.send(q.queue_id, {"name": "b.h5", "size": 500})
    queues.send(q.queue_id, {"name": "c.tiff", "size": 5})
    scheduler.drain(until=100.0)
    # one shared dispatch (3 messages < batch) — not one receive per trigger
    assert queues.stats["receives"] - before <= 2
    assert [h["name"] for h in hits["tiff"]] == ["a.tiff", "c.tiff"]
    assert [h["name"] for h in hits["big"]] == ["a.tiff", "b.h5"]
    assert hits["never"] == []
    # acked only after every trigger resolved each message
    assert queues.depth(q.queue_id) == 0


def test_quiet_queue_costs_no_receive_calls():
    """Push-first: an idle subscribed queue is not polled at all (the old
    per-trigger loops would poll forever at poll_max)."""
    router, queues, scheduler, clock = make_router()
    q = queues.create_queue("quiet")
    for _ in range(5):
        trig = router.create_trigger(TriggerConfig(
            queue_id=q.queue_id, predicate="True",
            action_invoker=lambda b, c: "r",
        ))
        router.enable(trig.trigger_id)
    scheduler.drain(until=10_000.0)
    # the enable-time backlog sweep is the only receive
    assert queues.stats["receives"] == 1


# ------------------------------------------------- at-least-once (regression)

def test_failed_invoker_leaves_message_unacked_and_redelivers():
    """Regression for the at-least-once violation: an invoker exception used
    to ack (and lose) the event.  Now the message stays unacked, the
    visibility timeout redelivers it, and a flaky invoker eventually fires
    exactly the failed events again."""
    router, queues, scheduler, clock = make_router()
    q = queues.create_queue("flaky", visibility_timeout=10.0)
    attempts: dict[int, int] = {}
    invoked = []

    def flaky(body, caller):
        n = body["n"]
        attempts[n] = attempts.get(n, 0) + 1
        if n % 2 == 0 and attempts[n] < 3:  # even events fail twice
            raise RuntimeError(f"transient failure for {n}")
        invoked.append(n)
        return f"run-{n}"

    trig = router.create_trigger(TriggerConfig(
        queue_id=q.queue_id, predicate="True", action_invoker=flaky,
        transform={"n": "n"},
    ))
    router.enable(trig.trigger_id)
    for n in range(6):
        queues.send(q.queue_id, {"n": n})
    scheduler.drain(until=1.0)
    # first pass: odd events invoked once; even events failed, NOT acked
    assert sorted(invoked) == [1, 3, 5]
    assert queues.depth(q.queue_id) == 3
    # visibility timeout elapses -> exactly the failed events are redelivered
    scheduler.drain(until=1000.0)
    assert sorted(invoked) == [0, 1, 2, 3, 4, 5]
    # the succeeded events fired exactly once; failed ones retried to success
    assert invoked.count(1) == invoked.count(3) == invoked.count(5) == 1
    assert attempts[0] == attempts[2] == attempts[4] == 3
    assert queues.depth(q.queue_id) == 0
    assert trig.stats["invocations"] == 6
    assert trig.stats["errors"] == 6  # 3 even events x 2 failures


def test_failed_invoker_does_not_stall_full_batch_backlog():
    """One poisoned message in a full batch must not delay the rest of the
    already-receivable backlog until the visibility deadline."""
    router, queues, scheduler, clock = make_router()
    q = queues.create_queue("backlog", visibility_timeout=30.0)
    invoked = []
    failures = [0]

    def invoker(body, caller):
        if body["n"] == 0 and failures[0] < 2:
            failures[0] += 1
            raise RuntimeError("transiently poisoned")
        invoked.append((clock.now(), body["n"]))
        return "r"

    trig = router.create_trigger(TriggerConfig(
        queue_id=q.queue_id, predicate="True", transform={"n": "n"},
        action_invoker=invoker, batch=4,
    ))
    for n in range(12):  # 3 full batches queued before enable
        queues.send(q.queue_id, {"n": n})
    router.enable(trig.trigger_id)
    scheduler.drain(until=1.0)
    # everything receivable was drained immediately (n=0 pending retry)
    assert [n for _, n in invoked] == list(range(1, 12))
    assert all(t <= 1.0 for t, _ in invoked)
    scheduler.drain(until=1000.0)  # visibility deadline retries n=0
    assert queues.depth(q.queue_id) == 0
    assert trig.stats["invocations"] == 12


def test_partial_failure_does_not_reinvoke_succeeded_triggers():
    """Two triggers match one message; one fails.  On redelivery only the
    failed trigger retries (resolved-set dedup)."""
    router, queues, scheduler, clock = make_router()
    q = queues.create_queue("pair", visibility_timeout=5.0)
    good_calls, bad_calls = [], []

    def good(body, caller):
        good_calls.append(body["n"])
        return "run-good"

    def bad(body, caller):
        bad_calls.append(body["n"])
        if len(bad_calls) < 3:
            raise RuntimeError("not yet")
        return "run-bad"

    for invoker in (good, bad):
        trig = router.create_trigger(TriggerConfig(
            queue_id=q.queue_id, predicate="True", action_invoker=invoker,
            transform={"n": "n"},
        ))
        router.enable(trig.trigger_id)
    queues.send(q.queue_id, {"n": 7})
    scheduler.drain(until=1000.0)
    assert good_calls == [7]          # fired once, never re-invoked
    assert bad_calls == [7, 7, 7]     # retried until success
    assert queues.depth(q.queue_id) == 0


def test_unauthorized_trigger_denied_without_killing_dispatch():
    """Per-trigger Receiver authorization on the shared dispatch: a trigger
    enabled by a caller without the Receiver role never sees message bodies
    (paper: the enabling token must carry the Queues receive scope) and is
    durably disabled — while authorized co-subscribers keep flowing."""
    from repro.core.auth import AuthService, Caller

    clock = VirtualClock()
    scheduler = Scheduler(clock)
    auth = AuthService()
    alice = Caller(identity=auth.create_identity("alice"))
    mallory = Caller(identity=auth.create_identity("mallory"))
    queues = QueueService(clock=clock, auth=auth)
    q = queues.create_queue(
        "secure", senders=["user:alice"], receivers=["user:alice"],
        caller=alice,
    )
    router = EventRouter(queues, clock=clock, scheduler=scheduler)
    invoked = []
    blocked = router.create_trigger(TriggerConfig(
        queue_id=q.queue_id, predicate="True", transform={"n": "n"},
        action_invoker=lambda b, c: "r",
    ))
    allowed = router.create_trigger(TriggerConfig(
        queue_id=q.queue_id, predicate="True", transform={"n": "n"},
        action_invoker=lambda b, c: invoked.append(b["n"]) or "r",
    ))
    router.enable(blocked.trigger_id, caller=mallory)  # no Receiver role
    router.enable(allowed.trigger_id, caller=alice)
    queues.send(q.queue_id, {"n": 1}, caller=alice)
    scheduler.drain(until=100.0)
    # mallory's trigger never saw the event and was disabled (with an error
    # note); alice's trigger received and invoked normally
    assert invoked == [1]
    assert blocked.stats["events"] == 0
    assert blocked.enabled is False
    assert any("Forbidden" in r.get("error", "")
               for r in blocked.recent_results)
    assert allowed.enabled is True and allowed.stats["events"] == 1
    assert queues.depth(q.queue_id) == 0


# ------------------------------------------------------------ durable triggers

def test_trigger_journal_and_recovery(tmp_path):
    journal_path = str(tmp_path / "journal.jsonl")
    queue_path = str(tmp_path / "queues.json")

    clock = VirtualClock()
    scheduler = Scheduler(clock)
    queues = QueueService(clock=clock, persist_path=queue_path)
    q = queues.create_queue("durable", visibility_timeout=8.0)
    router = EventRouter(queues, clock=clock, scheduler=scheduler,
                         journal=Journal(journal_path))
    invoked = []
    trig = router.create_trigger(TriggerConfig(
        queue_id=q.queue_id, predicate="n < 100",
        action_invoker=lambda body, c: invoked.append(body["n"]) or "r",
        transform={"n": "n"}, action_ref="test:counter",
    ))
    off = router.create_trigger(TriggerConfig(
        queue_id=q.queue_id, predicate="True",
        action_invoker=lambda body, c: "r", action_ref="test:off",
    ))
    router.enable(trig.trigger_id)
    router.enable(off.trigger_id)
    router.disable(off.trigger_id)
    for n in range(3):
        queues.send(q.queue_id, {"n": n})
    scheduler.drain(until=1.0)
    assert invoked == [0, 1, 2]

    # messages sent while the service is down survive in the queue backlog
    queues.send(q.queue_id, {"n": 50})

    # "restart": fresh clock/scheduler/queues/router over the same files
    clock2 = VirtualClock(start=clock.now())
    sched2 = Scheduler(clock2)
    queues2 = QueueService(clock=clock2, persist_path=queue_path)
    router2 = EventRouter(queues2, clock=clock2, scheduler=sched2,
                          journal=Journal(journal_path))
    invoked2 = []
    recovered = router2.recover(
        lambda image: (lambda body, c: invoked2.append(body["n"]) or "r")
    )
    by_id = {t.trigger_id: t for t in recovered}
    assert set(by_id) == {trig.trigger_id, off.trigger_id}
    assert by_id[trig.trigger_id].enabled is True
    assert by_id[off.trigger_id].enabled is False
    # stats survived via the journaled ack-progress snapshots
    assert by_id[trig.trigger_id].stats["invocations"] == 3
    sched2.drain(until=1000.0)
    # backlog drained by the recovery sweep; already-resolved events not re-run
    assert invoked2 == [50]
    assert queues2.depth(q.queue_id) == 0


def test_recovery_survives_vanished_queue(tmp_path):
    """A journaled trigger whose queue no longer exists recovers disabled;
    recovery continues to the remaining triggers instead of aborting."""
    journal_path = str(tmp_path / "journal.jsonl")
    clock = VirtualClock()
    scheduler = Scheduler(clock)
    queues = QueueService(clock=clock)  # no persistence: queues die with it
    q_gone = queues.create_queue("gone")
    q_kept = queues.create_queue("kept")
    router = EventRouter(queues, clock=clock, scheduler=scheduler,
                         journal=Journal(journal_path))
    orphan = router.create_trigger(TriggerConfig(
        queue_id=q_gone.queue_id, predicate="True",
        action_invoker=lambda b, c: "r",
    ))
    survivor = router.create_trigger(TriggerConfig(
        queue_id=q_kept.queue_id, predicate="True", transform={"n": "n"},
        action_invoker=lambda b, c: "r",
    ))
    router.enable(orphan.trigger_id)
    router.enable(survivor.trigger_id)

    # restart with only the kept queue re-created (same id)
    clock2 = VirtualClock(start=clock.now())
    sched2 = Scheduler(clock2)
    queues2 = QueueService(clock=clock2)
    queues2._queues[q_kept.queue_id] = q_kept  # simulate persisted queue
    router2 = EventRouter(queues2, clock=clock2, scheduler=sched2,
                          journal=Journal(journal_path))
    invoked = []
    recovered = router2.recover(
        lambda image: (lambda b, c: invoked.append(b.get("n")) or "r")
    )
    by_id = {t.trigger_id: t for t in recovered}
    assert set(by_id) == {orphan.trigger_id, survivor.trigger_id}
    assert by_id[orphan.trigger_id].enabled is False  # queue vanished
    assert by_id[survivor.trigger_id].enabled is True
    queues2.send(q_kept.queue_id, {"n": 9})
    sched2.drain(until=100.0)
    assert invoked == [9]  # the surviving trigger still flows


def test_recovery_survives_whitelist_violating_predicate(tmp_path):
    """The parse-only compiler journaled triggers whose predicates violate
    the whitelist (they just discarded every event at match time); recovery
    of such a journal must restore them — still discarding — and must not
    abort before the valid triggers behind them."""
    journal_path = str(tmp_path / "journal.jsonl")
    clock = VirtualClock()
    queues = QueueService(clock=clock)
    q = queues.create_queue("events")
    # hand-write the journal an old (parse-only) process would have left:
    # a parseable but whitelist-violating predicate, then a valid trigger
    journal = Journal(journal_path)
    for tid, pred in (("trig-bad", "[f for f in files]"),
                      ("trig-good", "n > 1")):
        journal.append({"type": "trigger_created", "trigger_id": tid,
                        "queue_id": q.queue_id, "predicate": pred,
                        "transform": {"n": "n"}, "action_ref": "",
                        "owner": "o", "t": 0.0})
        journal.append({"type": "trigger_enabled", "trigger_id": tid,
                        "t": 0.0})
    journal.close()

    scheduler = Scheduler(clock)
    router = EventRouter(queues, clock=clock, scheduler=scheduler,
                         journal=Journal(journal_path))
    invoked = []
    recovered = router.recover(
        lambda image: (lambda b, c: invoked.append((image.trigger_id,
                                                    b.get("n"))) or "r")
    )
    assert {t.trigger_id for t in recovered} == {"trig-bad", "trig-good"}
    queues.send(q.queue_id, {"n": 9, "files": ["a"]})
    scheduler.drain(until=100.0)
    # the valid trigger fires; the bad predicate discards, as it always did
    assert invoked == [("trig-good", 9)]
    assert router.get("trig-bad").stats["discarded"] == 1

    # genuinely unparseable predicates still fail at create time
    with pytest.raises(Exception):
        router.create_trigger(TriggerConfig(
            queue_id=q.queue_id, predicate="n >",
            action_invoker=lambda b, c: "r",
        ))


def test_recovery_dedups_inflight_invocations(tmp_path):
    """Crash after an invocation but before the ack: the journaled
    ack-progress prevents a duplicate invocation on redelivery."""
    journal_path = str(tmp_path / "journal.jsonl")
    queue_path = str(tmp_path / "queues.json")
    clock = VirtualClock()
    scheduler = Scheduler(clock)
    queues = QueueService(clock=clock, persist_path=queue_path)
    q = queues.create_queue("inflight", visibility_timeout=5.0)
    calls = []

    def invoker(body, caller):
        calls.append(body["n"])
        if body["n"] == 1:
            raise RuntimeError("fail so the batch stays unacked")
        return "r"

    router = EventRouter(queues, clock=clock, scheduler=scheduler,
                         journal=Journal(journal_path))
    trig = router.create_trigger(TriggerConfig(
        queue_id=q.queue_id, predicate="True", action_invoker=invoker,
        transform={"n": "n"},
    ))
    router.enable(trig.trigger_id)
    queues.send(q.queue_id, {"n": 0})
    queues.send(q.queue_id, {"n": 1})
    scheduler.drain(until=1.0)  # n=0 invoked+journaled; n=1 failed (unacked)
    assert calls == [0, 1]

    clock2 = VirtualClock(start=clock.now())
    sched2 = Scheduler(clock2)
    queues2 = QueueService(clock=clock2, persist_path=queue_path)
    router2 = EventRouter(queues2, clock=clock2, scheduler=sched2,
                          journal=Journal(journal_path))
    calls2 = []
    router2.recover(lambda image: (lambda body, c: calls2.append(body["n"]) or "r"))
    sched2.drain(until=1000.0)
    # n=0 was resolved pre-crash (journaled) -> only n=1 is re-invoked
    assert calls2 == [1]
    assert queues2.depth(q.queue_id) == 0


# ----------------------------------------------- FlowsService routing APIs

def test_flows_service_trigger_api_routes_to_runs():
    flows, queues, clock = make_flows(shards=4)
    record = flows.publish_flow(ECHO_FLOW, title="echo")
    q = queues.create_queue("frames")
    trig = flows.create_trigger(
        queue_id=q.queue_id,
        predicate='kind == "frame"',
        flow_id=record.flow_id,
        transform={"msg": "name"},
    )
    flows.enable_trigger(trig.trigger_id)
    queues.send(q.queue_id, {"kind": "frame", "name": "f0"})
    queues.send(q.queue_id, {"kind": "noise", "name": "x"})
    queues.send(q.queue_id, {"kind": "frame", "name": "f1"})
    flows.engine.drain(until=1000.0)
    status = flows.trigger_status(trig.trigger_id)
    assert status["enabled"] is True
    assert status["stats"]["invocations"] == 2
    assert status["stats"]["discarded"] == 1
    assert status["action_ref"] == f"flow:{record.flow_id}"
    runs = flows.list_runs(flow_id=record.flow_id)
    assert len(runs) == 2
    assert all(r["status"] == "SUCCEEDED" for r in runs)
    outputs = sorted(r["details"]["output"]["msg"] for r in runs)
    assert outputs == ["f0", "f1"]
    flows.disable_trigger(trig.trigger_id)
    assert flows.trigger_status(trig.trigger_id)["enabled"] is False
    with pytest.raises(NotFound):
        flows.create_trigger(q.queue_id, "True", "missing-flow")


def test_flows_service_without_queues_raises():
    clock = VirtualClock()
    registry = ActionRegistry()
    registry.register(EchoProvider(clock=clock))
    flows = FlowsService(registry, clock=clock)
    with pytest.raises(NotFound):
        flows.create_trigger("q-x", "True", "flow-y")


# -------------------------------------------- fault injection (event storm)

STORM_TRIGGERS = 8
STORM_MESSAGES = 200
STORM_KINDS = 4


def _storm_setup(journal_path, queue_path, clock, queue_id=None):
    queues = QueueService(clock=clock, persist_path=queue_path)
    flows, queues, clock = make_flows(
        shards=4, journal_path=journal_path, queues=queues, clock=clock
    )
    record = flows.publish_flow(ECHO_FLOW, title="storm", flow_id="storm-flow")
    return flows, queues, record


def test_event_storm_crash_recovery(tmp_path):
    """Kill a 4-shard FlowsService mid-event-storm; recover(); every matched
    event produced >= 1 invocation and trigger stats/enabled state survived."""
    journal_path = str(tmp_path / "journal.jsonl")
    queue_path = str(tmp_path / "queues.json")

    clock1 = VirtualClock()
    flows1, queues1, record1 = _storm_setup(journal_path, queue_path, clock1)
    q = queues1.create_queue("storm", visibility_timeout=20.0)
    for i in range(STORM_TRIGGERS):
        trig = flows1.create_trigger(
            queue_id=q.queue_id,
            predicate=f"kind == {i % STORM_KINDS}",
            flow_id="storm-flow",
            transform={"msg": "name"},
            trigger_id=f"trig-{i:02d}",
        )
        flows1.enable_trigger(trig.trigger_id)
    sent: dict[str, int] = {}  # message_id -> kind
    for j in range(STORM_MESSAGES):
        mid = queues1.send(
            q.queue_id,
            {"kind": j % STORM_KINDS, "name": f"m{j:03d}"},
            delay=j * 0.05,  # storm spread over 10 s
        )
        sent[mid] = j % STORM_KINDS
    # crash mid-storm: roughly half the messages delivered
    flows1.engine.drain(until=5.0)
    pre_crash = {
        tid: flows1.trigger_status(tid)["stats"]["invocations"]
        for tid in (f"trig-{i:02d}" for i in range(STORM_TRIGGERS))
    }
    assert 0 < sum(pre_crash.values()) < STORM_MESSAGES * 2  # genuinely mid-storm
    flows1.engine.shutdown()

    # restart on the same journal segments + queue file
    clock2 = VirtualClock(start=5.0)
    flows2, queues2, record2 = _storm_setup(journal_path, queue_path, clock2)
    flows2.recover_runs()
    recovered = flows2.recover_triggers()
    assert sorted(t.trigger_id for t in recovered) == [
        f"trig-{i:02d}" for i in range(STORM_TRIGGERS)
    ]
    # enabled state and stats survived the crash
    for tid, pre in pre_crash.items():
        status = flows2.trigger_status(tid)
        assert status["enabled"] is True
        assert status["stats"]["invocations"] == pre
    flows2.engine.drain(until=10_000.0)

    # every matched event produced >= 1 invocation on every matching trigger:
    # union the journaled ack-progress across both lives of the service
    invoked_by_trigger: dict[str, set[str]] = {}
    for journal in flows2.engine.journals:
        for image in replay_triggers(journal).values():
            invoked_by_trigger.setdefault(image.trigger_id, set()).update(
                image.invoked_message_ids
            )
    for i in range(STORM_TRIGGERS):
        matching = {mid for mid, kind in sent.items()
                    if kind == i % STORM_KINDS}
        missed = matching - invoked_by_trigger[f"trig-{i:02d}"]
        assert not missed, f"trig-{i:02d} missed {len(missed)} matched events"
    # the storm fully drains
    assert queues2.depth(q.queue_id) == 0


# --------------------------------------------------- determinism across shards

def _router_workload(num_shards):
    """Fixed trigger + message schedule; returns the router dispatch log."""
    flows, queues, clock = make_flows(shards=num_shards)
    flows.publish_flow(ECHO_FLOW, title="det", flow_id="det-flow")
    q = queues.create_queue("det")
    for i in range(6):
        trig = flows.create_trigger(
            queue_id=q.queue_id,
            predicate=f"n % 3 == {i % 3}",
            flow_id="det-flow",
            transform={"msg": "name"},
            trigger_id=f"det-trig-{i}",
        )
        flows.enable_trigger(trig.trigger_id)
    name_of: dict[str, str] = {}

    def send(j):
        mid = queues.send(q.queue_id, {"n": j, "name": f"m{j}"})
        name_of[mid] = f"m{j}"

    for j in range(40):
        # distinct send times: scheduled through the pool facade
        flows.engine.scheduler.call_at(1.0 + j * 0.73, lambda j=j: send(j))
    flows.engine.drain(until=10_000.0)
    assert queues.depth(q.queue_id) == 0
    # message ids are random per process; normalize to the message's name
    return [
        (t, trigger_id, name_of[mid], disposition)
        for t, trigger_id, mid, disposition in flows.router.dispatch_log
    ]


def test_router_dispatch_identical_across_shard_counts():
    """VirtualClock dispatch is bit-identical at shards 1, 4, 8."""
    baseline = _router_workload(1)
    assert len(baseline) == 40 * 6  # every trigger saw every message
    for n in (4, 8):
        assert _router_workload(n) == baseline
