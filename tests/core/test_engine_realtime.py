"""Real-clock engine smoke tests: threaded dispatcher + concurrent clients
(the execution mode behind the Fig 7 throughput benchmark)."""

import threading

from repro.core import asl
from repro.core.actions import ActionRegistry
from repro.core.clock import RealClock
from repro.core.engine import RUN_SUCCEEDED, FlowEngine, PollingPolicy
from repro.core.providers import EchoProvider, SleepProvider

PASS_FLOW = asl.parse(
    {"StartAt": "Noop", "States": {"Noop": {"Type": "Pass", "End": True}}}
)


def test_concurrent_clients_real_clock():
    clock = RealClock()
    registry = ActionRegistry()
    registry.register(EchoProvider(clock=clock))
    engine = FlowEngine(registry, clock=clock, max_workers=4)
    try:
        results = []
        lock = threading.Lock()

        def client(n):
            for _ in range(5):
                run = engine.start_run(PASS_FLOW, {"n": n}, flow_id="pass")
                engine.wait(run.run_id, timeout=10.0)
                with lock:
                    results.append(run.status)

        threads = [threading.Thread(target=client, args=(i,)) for i in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=30.0)
        assert len(results) == 40
        assert all(s == RUN_SUCCEEDED for s in results)
    finally:
        engine.shutdown()


def test_async_action_real_clock_callbacks():
    clock = RealClock()
    registry = ActionRegistry()
    sleep = SleepProvider(clock=clock)
    registry.register(sleep)
    engine = FlowEngine(
        registry,
        clock=clock,
        polling=PollingPolicy(initial_seconds=0.05, cap_seconds=0.5,
                              use_callbacks=True),
        max_workers=2,
    )
    sleep.scheduler = engine.scheduler
    try:
        flow = asl.parse(
            {"StartAt": "S",
             "States": {"S": {"Type": "Action", "ActionUrl": "ap://sleep",
                               "Parameters": {"seconds": 0.2},
                               "ResultPath": "$.r", "End": True}}}
        )
        run = engine.start_run(flow, {}, flow_id="sleepy")
        engine.wait(run.run_id, timeout=10.0)
        assert run.status == RUN_SUCCEEDED
        elapsed = run.completion_time - run.start_time
        assert elapsed < 2.0
    finally:
        engine.shutdown()
