"""Delegation chains across space-time: every provider invocation is
authorized with a live scoped token (ARCHITECTURE invariant 11).

The paper's hard case (§5.3): a flow outlives its tokens — parked for weeks
(passivation) or interrupted by a crash — yet every action invocation it
makes after waking must present a live, scoped, consented token.  These
suites pin the whole chain:

* the rejection matrix — ``run``/``status``/``cancel``/``release`` each
  refuse expired, revoked, mis-scoped, and missing tokens with the precise
  machine-readable ``code``;
* wake-after-expiry — a passivated run's wallet transparently re-delegates
  against the standing consent (and fails with ``token_expired`` when it
  can't);
* crash recovery on a 4-shard pool — recovered runs re-present freshly
  re-delegated tokens (tokens are never journaled; consents persist);
* ASL ``Catch`` on the coded auth errors.
"""

import pytest

from repro.core import asl
from repro.core.actions import ActionRegistry
from repro.core.auth import AuthContext, AuthService
from repro.core.clock import VirtualClock
from repro.core.engine import RUN_FAILED, RUN_SUCCEEDED
from repro.core.errors import AuthError, ConsentRequired
from repro.core.flows_service import FlowsService
from repro.core.providers import EchoProvider
from repro.core.shard_pool import EngineShardPool

HORIZON = 1_000_000.0

WAIT_ECHO_FLOW = {
    "StartAt": "W",
    "States": {
        "W": {"Type": "Wait", "Seconds": 5000, "Next": "E"},
        "E": {"Type": "Action", "ActionUrl": "ap://echo",
              "Parameters": {"echo_string.$": "$.msg"},
              "ResultPath": "$.echoed", "End": True},
    },
}


def make_auth(lifetime=None):
    clock = VirtualClock()
    auth = AuthService(clock=clock, default_token_lifetime_s=lifetime)
    auth.create_identity("alice")
    return auth, clock


# ---------------------------------------------------------- rejection matrix


def test_every_provider_path_rejects_expired_and_unconsented_tokens():
    """The acceptance matrix: run/status/cancel/release each enforce expiry
    and consent at invocation time, with machine-readable codes."""
    auth, clock = make_auth()
    echo = EchoProvider(clock=clock, auth=auth)
    auth.grant_consent("alice", echo.scope)
    ident = auth.get_identity("alice")
    # a second scope to provoke scope_mismatch
    auth.register_resource_server("ap.other")
    auth.register_scope("ap.other", "urn:s:other")
    auth.grant_consent("alice", "urn:s:other")

    def ctx(token):
        # no auth handle: refresh is impossible, so the stale token reaches
        # require() and the provider surfaces the precise code
        return AuthContext(identity=ident, tokens={echo.scope: token})

    good = auth.issue_token("alice", echo.scope, lifetime_s=60.0)
    done = echo.run({"echo_string": "hi"}, caller=ctx(good))
    assert done.status == "SUCCEEDED"
    paths = {
        "run": lambda c: echo.run({"echo_string": "x"}, caller=c),
        "status": lambda c: echo.status(done.action_id, caller=c),
        "cancel": lambda c: echo.cancel(done.action_id, caller=c),
        "release": lambda c: echo.release(done.action_id, caller=c),
    }

    clock.advance(61.0)  # the wallet token expires
    for name, call in paths.items():
        with pytest.raises(AuthError) as exc:
            call(ctx(good))
        assert exc.value.code == "token_expired", name

    mismatched = auth.issue_token("alice", "urn:s:other")
    for name, call in paths.items():
        with pytest.raises(AuthError) as exc:
            call(ctx(mismatched))
        assert exc.value.code == "scope_mismatch", name

    for name, call in paths.items():
        with pytest.raises(AuthError) as exc:
            call(None)
        assert exc.value.code == "missing_token", name

    revoked = auth.issue_token("alice", echo.scope)
    auth.revoke_consent("alice", echo.scope)
    for name, call in paths.items():
        with pytest.raises(ConsentRequired) as exc:
            call(ctx(revoked))
        assert exc.value.code == "consent_required", name


# ------------------------------------------------------ wake after expiry


def make_pool(path, clock, auth, shards=4):
    registry = ActionRegistry()
    registry.register(EchoProvider(clock=clock, auth=auth))
    return registry, EngineShardPool(
        registry, num_shards=shards, clock=clock, journal_path=path,
        passivate_after=0.0,
    )


def test_passivated_run_redelegates_expired_wallet_on_wake(tmp_path):
    """Parked past its tokens' lifetime, a run wakes, re-delegates against
    the standing consent, and completes (post-wake acceptance path)."""
    auth, clock = make_auth()
    registry, pool = make_pool(str(tmp_path / "seg"), clock, auth)
    echo = registry.lookup("ap://echo")
    auth.grant_consent("alice", echo.scope)
    stale = auth.issue_token("alice", echo.scope, lifetime_s=100.0)
    caller = AuthContext(identity=auth.get_identity("alice"),
                         tokens={echo.scope: stale}, auth=auth)
    run = pool.start_run(asl.parse(WAIT_ECHO_FLOW), {"msg": "wake"},
                         caller=caller)
    pool.scheduler.drain(until=10.0)
    assert pool.dormant_stubs()  # parked at the Wait, paged out
    pool.scheduler.drain(until=HORIZON)  # wakes at t=5000; token died at 100
    woken = pool.get_run(run.run_id)
    assert woken.status == RUN_SUCCEEDED
    assert woken.context["echoed"]["details"]["echo_string"] == "wake"
    fresh = caller.tokens[echo.scope]
    assert fresh != stale and auth.token_live(fresh)


def test_wake_without_refresh_fails_with_token_expired(tmp_path):
    """No auth handle = no re-delegation: the woken run's invocation is
    rejected with the precise code (post-wake rejection path)."""
    auth, clock = make_auth()
    registry, pool = make_pool(str(tmp_path / "seg"), clock, auth)
    echo = registry.lookup("ap://echo")
    auth.grant_consent("alice", echo.scope)
    stale = auth.issue_token("alice", echo.scope, lifetime_s=100.0)
    caller = AuthContext(identity=auth.get_identity("alice"),
                         tokens={echo.scope: stale})  # auth=None
    run = pool.start_run(asl.parse(WAIT_ECHO_FLOW), {"msg": "x"},
                         caller=caller)
    pool.scheduler.drain(until=HORIZON)
    failed = pool.get_run(run.run_id)
    assert failed.status == RUN_FAILED
    assert failed.error["Error"] == "AuthError"
    assert failed.error["Details"] == {"code": "token_expired"}


# ------------------------------------------------------- crash + recovery


def make_flows(path, clock, auth, shards=4):
    registry = ActionRegistry()
    registry.register(EchoProvider(clock=clock, auth=auth))
    return FlowsService(registry, clock=clock, auth=auth, shards=shards,
                        journal_path=path)


def publish(svc):
    return svc.publish_flow(WAIT_ECHO_FLOW, owner="root",
                            starters=["all_authenticated_users"],
                            flow_id="chain-flow")


def test_recovered_runs_represent_redelegated_tokens(tmp_path):
    """Crash mid-flight on a 4-shard pool: tokens are never journaled, but
    consents persist — every recovered run re-presents a live wallet and
    completes (post-recovery acceptance path)."""
    path = str(tmp_path / "seg")
    auth, clock = make_auth(lifetime=30.0)
    svc = make_flows(path, clock, auth)
    record = publish(svc)
    auth.grant_consent("alice", record.scope)
    token = auth.issue_token("alice", record.scope)
    caller = AuthContext(identity=auth.get_identity("alice"),
                         tokens={record.scope: token}, auth=auth)
    runs = [svc.run_flow(record.flow_id, {"msg": f"m{i}"}, caller=caller)
            for i in range(8)]
    originals = {r.run_id: dict(r.caller.tokens) for r in runs}
    svc.engine.scheduler.drain(until=10.0)  # all parked mid-flight
    svc.engine.shutdown()  # crash

    clock.advance(10_000.0)  # down for hours: every original token expired
    svc2 = make_flows(path, clock, auth)
    record2 = publish(svc2)
    recovered = svc2.recover_runs()
    assert len(recovered) == 8
    closure = set(auth.dependency_closure(record2.scope))
    for run in recovered:
        assert run.caller is not None
        assert set(run.caller.tokens) == closure
        for scope, tok in run.caller.tokens.items():
            assert auth.token_live(tok), scope
            assert tok not in originals[run.run_id].values()
    svc2.engine.scheduler.drain(until=HORIZON)
    for run in recovered:
        assert run.status == RUN_SUCCEEDED
    svc2.engine.shutdown()


def test_consent_revoked_while_down_fails_recovered_run(tmp_path):
    """Re-delegation at recovery honors revocation: the run resumes without
    a wallet and its next invocation is rejected (post-recovery rejection)."""
    path = str(tmp_path / "seg")
    auth, clock = make_auth(lifetime=30.0)
    svc = make_flows(path, clock, auth)
    record = publish(svc)
    auth.grant_consent("alice", record.scope)
    token = auth.issue_token("alice", record.scope)
    caller = AuthContext(identity=auth.get_identity("alice"),
                         tokens={record.scope: token}, auth=auth)
    svc.run_flow(record.flow_id, {"msg": "m"}, caller=caller)
    svc.engine.scheduler.drain(until=10.0)
    svc.engine.shutdown()

    auth.revoke_consent("alice", record.scope)  # closure-wide, while down
    svc2 = make_flows(path, clock, auth)
    publish(svc2)
    (recovered,) = svc2.recover_runs()
    assert recovered.caller is None  # re-delegation refused
    svc2.engine.scheduler.drain(until=HORIZON)
    assert recovered.status == RUN_FAILED
    assert recovered.error["Error"] == "AuthError"
    assert recovered.error["Details"] == {"code": "missing_token"}
    svc2.engine.shutdown()


# ------------------------------------------------------------- ASL surface


def test_consent_required_is_catchable_from_asl():
    """Flows model re-consent with Catch: the coded auth error lands in the
    error doc (Error name + Details.code) and routes to the handler state."""
    clock = VirtualClock()
    auth = AuthService(clock=clock)
    auth.create_identity("alice")
    registry = ActionRegistry()
    registry.register(EchoProvider(clock=clock, auth=auth))
    svc = FlowsService(registry, clock=clock, auth=auth)
    record = svc.publish_flow(
        {
            "StartAt": "E",
            "States": {
                "E": {"Type": "Action", "ActionUrl": "ap://echo",
                      "Parameters": {"echo_string.$": "$.msg"},
                      "Catch": [{"ErrorEquals": ["ConsentRequired"],
                                 "ResultPath": "$.auth_error",
                                 "Next": "Reconsent"}],
                      "End": True},
                "Reconsent": {"Type": "Pass",
                              "Result": {"action": "ask the user again"},
                              "ResultPath": "$.plan", "End": True},
            },
        },
        owner="root", starters=["all_authenticated_users"],
    )
    auth.grant_consent("alice", record.scope)
    token = auth.issue_token("alice", record.scope)
    caller = AuthContext(identity=auth.get_identity("alice"),
                         tokens={record.scope: token}, auth=auth)
    run = svc.run_flow(record.flow_id, {"msg": "hi"}, caller=caller)
    # the user withdraws consent after the run starts but before the action
    # fires: the provider rejects the (revoked) wallet, the Catch routes
    auth.revoke_consent("alice", record.scope)
    svc.engine.scheduler.drain(until=HORIZON)
    assert run.status == RUN_SUCCEEDED
    assert run.context["auth_error"]["Error"] == "ConsentRequired"
    assert run.context["auth_error"]["Details"] == {"code": "consent_required"}
    assert run.context["plan"]["action"] == "ask the user again"
