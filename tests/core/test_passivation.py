"""Differential tests: passivated runs ≡ always-resident runs.

Passivation (engine ``passivate_after``) pages a parked run out to its
``run_passivated`` journal record and keeps only a :class:`DormantStub`.
Its correctness contract is *transparency* (docs/ARCHITECTURE.md invariant
9): for every flow and every parking point, the terminal state of a run
that was passivated and rehydrated — possibly many times, possibly across
a crash — is identical to the run that stayed resident throughout.

The suites force passivation at every eligible point (``passivate_after=
0.0``) over randomized linear flows mixing Pass / Wait / WaitPath /
long-poll Action states, and check the composition surfaces the feature
touches: crash injection around the ``run_passivated`` append (durable
record vs torn write), Map admission windows (children and joining parents
must never park), 4-shard pool recovery with re-parking, and delta vs
full-context journal encodings.

Uses the ``repro.testing`` hypothesis shim: the real hypothesis when
installed, a deterministic seeded sweep otherwise.
"""

import json
import random
import tempfile

import pytest

from repro.core import asl
from repro.core.actions import ActionRegistry
from repro.core.clock import VirtualClock
from repro.core.engine import RUN_SUCCEEDED, FlowEngine
from repro.core.journal import (
    Journal,
    JournalCrashed,
    SimulatedCrash,
    replay,
)
from repro.core.providers import EchoProvider, SleepProvider
from repro.core.shard_pool import EngineShardPool
from repro.testing import hypothesis_shim

given, settings, st = hypothesis_shim()

pytestmark = pytest.mark.slow

HORIZON = 10_000_000.0  # drain horizon: far past any generated wake-up


def make_engine(journal: Journal | None = None, **kwargs) -> FlowEngine:
    clock = VirtualClock()
    registry = ActionRegistry()
    registry.register(EchoProvider(clock=clock))
    registry.register(SleepProvider(clock=clock))
    return FlowEngine(registry, clock=clock, journal=journal or Journal(),
                      **kwargs)


def make_pool(path: str, shards: int = 4, **kwargs) -> EngineShardPool:
    clock = VirtualClock()
    registry = ActionRegistry()
    registry.register(EchoProvider(clock=clock))
    registry.register(SleepProvider(clock=clock))
    return EngineShardPool(registry, num_shards=shards, clock=clock,
                           journal_path=path, **kwargs)


def canon(doc):
    """Normalize legitimately nondeterministic fields.

    Action ids are random per process; ``started`` is the virtual time an
    action's sleep began, which differs when a rehydrated run re-enters its
    action state later than the resident reference polled it.
    """
    if isinstance(doc, dict):
        return {
            k: ("<nondet>" if k in ("action_id", "started") else canon(v))
            for k, v in doc.items()
        }
    if isinstance(doc, list):
        return [canon(v) for v in doc]
    return doc


def terminal(run) -> str:
    """The comparison key: status + full context, canonicalized to JSON.

    Works for live :class:`~repro.core.engine.Run` objects and replayed
    :class:`~repro.core.journal.RunImage` s alike, so a run that finished
    *before* a crash (recovery correctly leaves it unresumed) can still be
    compared through its journal image.
    """
    error = getattr(run, "error", None)
    return json.dumps(
        {"status": run.status, "context": canon(run.context),
         "error": canon(error) if isinstance(error, dict) else error},
        sort_keys=True,
    )


def recovered_terminal(engine_or_pool, journals, run_id) -> str:
    """Terminal key after a restart: the live (resumed) run if present,
    else the journal image of a run that completed before the crash."""
    from repro.core.errors import NotFound

    try:
        return terminal(engine_or_pool.get_run(run_id))
    except NotFound:
        for journal in journals:
            image = replay(journal).get(run_id)
            if image is not None:
                return terminal(image)
        raise


# ------------------------------------------------------- random flow builder

def random_linear_flow(rng: random.Random) -> tuple[dict, dict]:
    """A linear flow of 2..7 states drawn from the parking-relevant mix.

    Returns (definition, flow_input).  WaitPath states read their duration
    from the input so the SecondsPath parking path is exercised too.
    """
    states = {}
    flow_input = {"w": round(rng.uniform(0.0, 5000.0), 2)}
    names = []
    for i in range(rng.randint(2, 7)):
        name = f"S{i}"
        kind = rng.choice(["pass", "wait", "wait_path", "action"])
        if kind == "pass":
            states[name] = {"Type": "Pass", "Result": {"step": i},
                            "ResultPath": f"$.p{i}"}
        elif kind == "wait":
            states[name] = {"Type": "Wait",
                            "Seconds": round(rng.uniform(0.0, 100_000.0), 2)}
        elif kind == "wait_path":
            states[name] = {"Type": "Wait", "SecondsPath": "$.w"}
        else:
            states[name] = {
                "Type": "Action", "ActionUrl": "ap://sleep",
                "Parameters": {"seconds": round(rng.uniform(0.0, 500.0), 2)},
                "ResultPath": f"$.a{i}",
            }
        names.append(name)
    states[names[-1]]["End"] = True
    for prev, nxt in zip(names, names[1:]):
        states[prev]["Next"] = nxt
    return {"StartAt": names[0], "States": states}, flow_input


def run_resident(defn, flow_input, **kwargs):
    """Reference: the same flow on an engine with passivation disabled."""
    engine = make_engine(passivate_after=None, **kwargs)
    run = engine.start_run(asl.parse(defn), dict(flow_input), flow_id="f",
                           run_id="run-ref")
    engine.scheduler.drain(until=HORIZON)
    return run


# ----------------------------------------- property: forced parking ≡ resident

@settings(max_examples=30)
@given(st.integers(min_value=0, max_value=2**31))
def test_forced_passivation_matches_resident(seed):
    rng = random.Random(seed)
    defn, flow_input = random_linear_flow(rng)
    ref = run_resident(defn, flow_input)

    engine = make_engine(passivate_after=0.0)
    run = engine.start_run(asl.parse(defn), dict(flow_input), flow_id="f",
                           run_id="run-ref")
    engine.scheduler.drain(until=HORIZON)
    live = engine.get_run(run.run_id)

    assert live.status == ref.status == RUN_SUCCEEDED
    assert terminal(live) == terminal(ref)
    # every Wait (and every long-poll gap) was an eligible parking point
    n_waits = sum(1 for s in defn["States"].values() if s["Type"] == "Wait")
    assert engine.stats["runs_passivated"] >= n_waits
    assert engine.stats["runs_rehydrated"] == engine.stats["runs_passivated"]
    assert not engine.dormant


@settings(max_examples=15)
@given(st.integers(min_value=0, max_value=2**31))
def test_forced_passivation_matches_resident_across_restart(seed):
    """Kill the engine while parked; the re-parked run still converges."""
    rng = random.Random(seed)
    defn, flow_input = random_linear_flow(rng)
    if not any(s["Type"] == "Wait" for s in defn["States"].values()):
        defn["States"]["S0"] = {"Type": "Wait", "Seconds": 1000.0,
                                "Next": defn["StartAt"]}
        defn["StartAt"] = "S0"
    ref = run_resident(defn, flow_input)

    flow = asl.parse(defn)
    journal = Journal()  # in-memory journals survive engine objects
    engine1 = make_engine(journal=journal, passivate_after=0.0)
    run_id = engine1.start_run(flow, dict(flow_input), flow_id="f",
                               run_id="run-ref").run_id
    # stop mid-flight at a random moment (often while dormant)
    engine1.scheduler.drain(until=rng.uniform(0.0, 200_000.0))

    engine2 = make_engine(journal=journal, passivate_after=0.0)
    engine2.recover({"f": flow})
    engine2.scheduler.drain(until=HORIZON)
    assert recovered_terminal(engine2, [journal], run_id) == terminal(ref)


# ------------------------------------------------- crash around run_passivated

def _crash_engine(path, phase_to_kill, flow, flow_input):
    """Run with a fault hook killing at ``phase_to_kill`` of the FIRST
    run_passivated batch; returns after the simulated crash."""

    def hook(phase, batch):
        if phase == phase_to_kill and any(
            '"run_passivated"' in line for line in batch
        ):
            raise SimulatedCrash(f"killed at {phase}")

    journal = Journal(path, fault_hook=hook)
    engine = make_engine(journal=journal, passivate_after=0.0)
    run = engine.start_run(flow, dict(flow_input), flow_id="f",
                           run_id="run-ref")
    with pytest.raises((SimulatedCrash, JournalCrashed)):
        engine.scheduler.drain(until=HORIZON)
        raise JournalCrashed("flow finished without ever parking")
    return run.run_id


@settings(max_examples=10)
@given(st.integers(min_value=0, max_value=2**31),
       st.sampled_from(["pre-write", "post-fsync"]))
def test_crash_between_record_and_stub_drop(seed, phase):
    """Crash injection around the passivation append.

    ``post-fsync``: the run_passivated record is durable but the engine
    died before dropping the run — recovery must adopt the dormant image.
    ``pre-write``: the record was never written — recovery must resume the
    run resident in its Wait/Action state.  Either way the terminal state
    equals the never-passivated, never-crashed reference.
    """
    rng = random.Random(seed)
    defn, flow_input = random_linear_flow(rng)
    # guarantee at least one parking point so the hook always fires
    defn["States"]["Park"] = {"Type": "Wait", "Seconds": 5000.0,
                              "Next": defn["StartAt"]}
    defn["StartAt"] = "Park"
    flow = asl.parse(defn)
    ref = run_resident(defn, flow_input)

    path = tempfile.mkdtemp(prefix="passiv-crash-") + "/journal.jsonl"
    run_id = _crash_engine(path, phase, flow, flow_input)

    engine2 = make_engine(journal=Journal(path), passivate_after=0.0)
    engine2.recover({"f": flow})
    engine2.scheduler.drain(until=HORIZON)
    live = engine2.get_run(run_id)
    assert terminal(live) == terminal(ref)


def test_durable_record_crash_recovers_dormant(tmp_path):
    """The post-fsync crash specifically must re-park, not re-run: the run
    was journaled as passivated, so recovery adopts a stub (O(1) memory)
    and re-appends a fresh record for the new generation."""
    defn = {"StartAt": "Park",
            "States": {"Park": {"Type": "Wait", "Seconds": 5000.0,
                                "Next": "Done"},
                       "Done": {"Type": "Pass", "End": True}}}
    flow = asl.parse(defn)
    path = str(tmp_path / "journal.jsonl")
    run_id = _crash_engine(path, "post-fsync", flow, {})

    engine2 = make_engine(journal=Journal(path), passivate_after=0.0)
    engine2.recover({"f": flow})
    assert engine2.stats["runs_reparked"] == 1
    assert run_id in engine2.dormant
    stub = engine2.dormant[run_id]
    assert stub.as_status()["dormant"] is True
    assert stub.as_status()["current_state"] == "Park"
    engine2.scheduler.drain(until=HORIZON)
    assert engine2.get_run(run_id).status == RUN_SUCCEEDED


# --------------------------------------------------- composition: Map windows

MAP_ITERATOR = {
    "StartAt": "Work",
    "States": {
        "Work": {"Type": "Action", "ActionUrl": "ap://sleep",
                 "Parameters": {"seconds.$": "$.item"},
                 "ResultPath": "$.slept", "Next": "Echo"},
        "Echo": {"Type": "Action", "ActionUrl": "ap://echo",
                 "Parameters": {"echo_string.$": "$.index"},
                 "ResultPath": "$.echoed", "End": True},
    },
}


@settings(max_examples=10)
@given(st.integers(min_value=0, max_value=2**31))
def test_passivation_composes_with_map_admission(seed):
    """Waits around a Map park; the Map itself (joining parent + children
    inside the admission window) never does."""
    rng = random.Random(seed)
    items = [round(rng.uniform(0.0, 50.0), 2)
             for _ in range(rng.randint(1, 10))]
    window = rng.choice([0, 1, 2, 16])
    defn = {
        "StartAt": "Before",
        "States": {
            "Before": {"Type": "Wait", "Seconds": 4000.0, "Next": "Fan"},
            "Fan": {"Type": "Map", "ItemsPath": "$.xs",
                    "MaxConcurrency": window, "Iterator": MAP_ITERATOR,
                    "ResultPath": "$.results", "Next": "After"},
            "After": {"Type": "Wait", "Seconds": 9000.0, "Next": "Done"},
            "Done": {"Type": "Pass", "End": True},
        },
    }
    ref = run_resident(defn, {"xs": items})

    engine = make_engine(passivate_after=0.0)
    run = engine.start_run(asl.parse(defn), {"xs": items}, flow_id="f",
                           run_id="run-ref")
    engine.scheduler.drain(until=HORIZON)
    live = engine.get_run(run.run_id)

    assert terminal(live) == terminal(ref)
    # exactly the two Waits parked: Map children (they have a parent) and
    # the joining parent (map_join held) are ineligible by construction
    assert engine.stats["runs_passivated"] == 2
    if window:
        assert live.map_peak_live <= window


# ------------------------------------------------- composition: 4-shard pool

@settings(max_examples=8)
@given(st.integers(min_value=0, max_value=2**31))
def test_four_shard_recovery_with_passivation(seed):
    """A 4-shard pool full of parked runs crashes; the recovered pool
    (re-parking each shard's dormant images from its own segment) reaches
    the same terminals as an uninterrupted resident pool."""
    rng = random.Random(seed)
    flows, inputs = {}, {}
    for i in range(6):
        defn, flow_input = random_linear_flow(rng)
        flows[f"f{i}"] = asl.parse(defn)
        inputs[f"f{i}"] = flow_input

    base = tempfile.mkdtemp(prefix="passiv-shards-")
    ref_pool = make_pool(base + "/ref", passivate_after=None)
    refs = {}
    for i, (fid, flow) in enumerate(flows.items()):
        refs[fid] = ref_pool.start_run(flow, dict(inputs[fid]), flow_id=fid,
                                       run_id=f"run-{i}")
    ref_pool.scheduler.drain(until=HORIZON)

    path = base + "/crashed"
    pool1 = make_pool(path, passivate_after=0.0)
    for i, (fid, flow) in enumerate(flows.items()):
        pool1.start_run(flow, dict(inputs[fid]), flow_id=fid,
                        run_id=f"run-{i}")
    pool1.scheduler.drain(until=rng.uniform(0.0, 300_000.0))

    pool2 = make_pool(path, passivate_after=0.0)
    pool2.recover(flows, resume=True)
    pool2.scheduler.drain(until=HORIZON)
    for i, fid in enumerate(flows):
        got = recovered_terminal(pool2, pool2.journals, f"run-{i}")
        assert got == terminal(refs[fid]), fid


# --------------------------------------------- journal encodings + inspection

@settings(max_examples=10)
@given(st.integers(min_value=0, max_value=2**31), st.booleans())
def test_passivated_replay_matches_live_context(seed, delta):
    """Replaying a journal full of run_passivated records (riding either
    the delta or the full-context encoding) reproduces the live terminal
    context exactly."""
    rng = random.Random(seed)
    defn, flow_input = random_linear_flow(rng)
    journal = Journal()
    engine = make_engine(journal=journal, passivate_after=0.0,
                         delta_journal=delta)
    run = engine.start_run(asl.parse(defn), dict(flow_input), flow_id="f",
                           run_id="run-ref")
    engine.scheduler.drain(until=HORIZON)
    live = engine.get_run(run.run_id)
    assert live.status == RUN_SUCCEEDED

    image = replay(journal)[run.run_id]
    assert image.status == RUN_SUCCEEDED
    assert json.dumps(image.context, sort_keys=True) == json.dumps(
        live.context, sort_keys=True
    )


def test_stub_status_answers_without_rehydration():
    """as_status() on a dormant run is served by the stub; explicit wake
    rehydrates with the original deadline preserved."""
    defn = {"StartAt": "Park",
            "States": {"Park": {"Type": "Wait", "Seconds": 7000.0,
                                "Next": "Done"},
                       "Done": {"Type": "Pass",
                                "Result": {"ok": True},
                                "ResultPath": "$.done", "End": True}}}
    engine = make_engine(passivate_after=60.0)
    run = engine.start_run(asl.parse(defn), {"x": 1}, flow_id="f")
    engine.scheduler.drain(until=10.0)

    status = engine.run_status(run.run_id)
    assert status["dormant"] is True
    assert status["current_state"] == "Park"
    assert status["wake_time"] == 7000.0
    assert run.run_id in engine.dormant  # no rehydration happened

    assert engine.wake_run(run.run_id) is True
    assert run.run_id not in engine.dormant
    live = engine.get_run(run.run_id)
    assert live.current_state == "Park"  # deadline preserved, wait re-armed
    engine.scheduler.drain(until=HORIZON)
    assert engine.get_run(run.run_id).status == RUN_SUCCEEDED
    assert engine.get_run(run.run_id).context["done"] == {"ok": True}
