"""Process backend: spawn-safe journals, inline equivalence, SIGKILL failover.

The acceptance property is *backend equivalence* (ARCHITECTURE invariant
13): for the same flows and inputs, :class:`~repro.core.backend.InlineBackend`
(thread-per-shard, in-process) and
:class:`~repro.core.process_backend.ProcessBackend` (shard groups in spawned
worker processes) produce the same terminal state for every run — the
process boundary is an execution detail, never a semantic one.  On top of
that sits the failure model: SIGKILL of one worker mid-storm must recover
every run exactly once (journaled dedup + fencing epochs), matching the
uninterrupted reference.

The journal tests pin the fd-inheritance contract that makes worker-hosted
segments safe at all: a :class:`~repro.core.journal.Journal` opens its file
handle lazily in the *owning* process, so a segment written before a spawn
round-trips in the worker with fencing intact, and a handle inherited
across ``fork`` is re-opened rather than written through.
"""

from __future__ import annotations

import multiprocessing as mp
import os
import signal
import time

import pytest

from repro.core import asl
from repro.core.actions import ActionRegistry
from repro.core.auth import Tenant
from repro.core.backend import ExecutionBackend, InlineBackend, make_backend
from repro.core.chaos import ChaosPlane
from repro.core.clock import RealClock
from repro.core.engine import RUN_SUCCEEDED
from repro.core.journal import (
    Journal,
    JournalFenced,
    replay_segment,
    segment_path,
)
from repro.core.process_backend import ProcessBackend
from repro.core.providers import EchoProvider, SleepProvider
from repro.core.shard_pool import EngineShardPool

#: worker processes rebuild their registries from this spec — echo + sleep,
#: the same providers the inline reference uses
REGISTRY_SPEC = "repro.core.process_backend:default_registry"

WAIT_S = 120.0

ECHO = {
    "StartAt": "E",
    "States": {
        "E": {"Type": "Action", "ActionUrl": "ap://echo",
              "Parameters": {"echo_string.$": "$.msg"},
              "ResultPath": "$.r", "End": True},
    },
}

#: Map fan-out: children co-locate with the parent inside a worker process,
#: but the rolled-up result must match the inline pool's cross-shard spread
MAP_FAN = {
    "StartAt": "Fan",
    "States": {
        "Fan": {
            "Type": "Map",
            "ItemsPath": "$.xs",
            "MaxConcurrency": 4,
            "Iterator": {
                "StartAt": "Echo",
                "States": {
                    "Echo": {"Type": "Action", "ActionUrl": "ap://echo",
                             "Parameters": {"echo_string.$": "$.index"},
                             "ResultPath": "$.echoed", "End": True},
                },
            },
            "ResultPath": "$.results",
            "End": True,
        },
    },
}

#: the storm flow holds each run in flight long enough for a SIGKILL to
#: land mid-run (real seconds: the process backend runs on a real clock)
CHAIN = {
    "StartAt": "A",
    "States": {
        "A": {"Type": "Action", "ActionUrl": "ap://echo",
              "Parameters": {"echo_string.$": "$.msg"},
              "ResultPath": "$.a", "Next": "Pause"},
        "Pause": {"Type": "Action", "ActionUrl": "ap://sleep",
                  "Parameters": {"seconds": 0.1},
                  "ResultPath": "$.pause", "Next": "B"},
        "B": {"Type": "Action", "ActionUrl": "ap://echo",
              "Parameters": {"echo_string.$": "$.a.details.echo_string"},
              "ResultPath": "$.b", "End": True},
    },
}


def fresh_registry() -> ActionRegistry:
    registry = ActionRegistry()
    registry.register(EchoProvider())
    registry.register(SleepProvider())
    return registry


def submit_workload(backend) -> dict[str, object]:
    """The shared differential workload: echo runs (a third of them
    tenant-stamped and metered through admission), plus Map fan-outs."""
    echo_flow = asl.parse(ECHO)
    fan_flow = asl.parse(MAP_FAN)
    acme = Tenant(tenant_id="acme", max_concurrency=2)
    handles = {}
    for i in range(12):
        kwargs = {"tenant": acme} if i % 3 == 0 else {}
        h = backend.start_run(echo_flow, {"msg": f"m{i}"}, flow_id="echo",
                              run_id=f"run-e{i:02d}", **kwargs)
        handles[h.run_id] = h
    for i in range(3):
        h = backend.start_run(fan_flow, {"xs": list(range(8))},
                              flow_id="fan", run_id=f"run-f{i}")
        handles[h.run_id] = h
    for rid in handles:
        assert backend.wait(rid, timeout=WAIT_S).status == RUN_SUCCEEDED, rid
    return handles


def project(ctx: dict):
    """The semantically-meaningful slice of a terminal context (action
    envelopes carry per-execution ids/timestamps that legitimately differ
    between backends)."""
    if "results" in ctx:
        return [item["echoed"]["details"]["echo_string"]
                for item in ctx["results"]]
    return ctx["r"]["details"]["echo_string"]


def signature(handles) -> dict[str, tuple]:
    return {rid: (h.status, h.tenant_id, project(h.context))
            for rid, h in handles.items()}


# ------------------------------------------------------------ backend seam

def test_make_backend_thread_is_inline_pool(tmp_path):
    backend = make_backend("thread", fresh_registry(), num_shards=2,
                           clock=RealClock(),
                           journal_path=str(tmp_path / "j.jsonl"))
    try:
        assert isinstance(backend, InlineBackend)
        assert isinstance(backend, EngineShardPool)
        assert isinstance(backend, ExecutionBackend)
        assert backend.backend_name == "thread"
    finally:
        backend.shutdown()


def test_make_backend_process_rejects_inline_only_knobs():
    with pytest.raises(ValueError, match="journals="):
        make_backend("process", fresh_registry(), journals=[object()],
                     options={"registry_spec": REGISTRY_SPEC})
    with pytest.raises(ValueError, match="registry_spec"):
        make_backend("process", fresh_registry())
    with pytest.raises(ValueError, match="unknown execution backend"):
        make_backend("carrier-pigeon", fresh_registry())


# --------------------------------------------------- journal spawn safety

def _spawn_probe(path: str, conn) -> None:
    """Reopen a pre-spawn segment in a worker process and extend it."""
    journal = Journal(path)
    seen_epoch = journal.epoch
    new_epoch = journal.bump_epoch("worker takeover")
    journal.append({"type": "note", "who": "child", "pid": os.getpid()})
    journal.close()
    conn.send({"seen_epoch": seen_epoch, "new_epoch": new_epoch})
    conn.close()


def test_journal_segment_round_trips_across_spawn(tmp_path):
    """A segment written before a spawn is reopened in the worker with
    fencing intact: the worker sees the parent's epoch, supersedes it, and
    the fenced pre-spawn handle can never append again."""
    path = segment_path(str(tmp_path / "journal.jsonl"), 0, 2)
    journal = Journal(path)
    journal.append({"type": "note", "who": "parent", "pid": os.getpid()})
    assert journal.bump_epoch("pre-spawn handoff") == 1
    # the parent handle stays open (lazily, in this pid) across the spawn
    ctx = mp.get_context("spawn")
    recv, send = ctx.Pipe(duplex=False)
    proc = ctx.Process(target=_spawn_probe, args=(path, send))
    proc.start()
    proc.join(60)
    assert proc.exitcode == 0
    assert recv.recv() == {"seen_epoch": 1, "new_epoch": 2}
    # the superseded pre-spawn holder is fenced; its late appends bounce
    journal.fence("superseded by spawned successor")
    with pytest.raises(JournalFenced):
        journal.append({"type": "note", "who": "zombie"})
    journal.close()
    # a fresh reader sees both writers' records under the highest epoch
    reader = Journal(path)
    assert reader.epoch == 2
    notes = [r["who"] for r in reader.records() if r.get("type") == "note"]
    assert notes == ["parent", "child"]
    reader.close()


def _fork_appender(journal: Journal, conn) -> None:
    try:
        journal.append({"type": "note", "who": "forked-child",
                        "pid": os.getpid()})
        conn.send(("ok", journal._fh_pid))
    except BaseException as exc:  # pragma: no cover - diagnostic path
        conn.send(("err", repr(exc)))
    finally:
        conn.close()


def test_inherited_fh_reopened_not_shared(tmp_path):
    """A journal object carried across ``fork`` must not write through the
    parent's inherited file handle: the child re-opens under its own pid,
    and the parent's handle keeps working afterwards."""
    if "fork" not in mp.get_all_start_methods():
        pytest.skip("platform has no fork start method")
    path = str(tmp_path / "seg.jsonl")
    journal = Journal(path)
    journal.append({"type": "note", "who": "parent-1"})  # fh now open here
    ctx = mp.get_context("fork")
    recv, send = ctx.Pipe(duplex=False)
    proc = ctx.Process(target=_fork_appender, args=(journal, send))
    proc.start()
    proc.join(60)
    assert proc.exitcode == 0
    status, owner_pid = recv.recv()
    assert status == "ok"
    assert owner_pid == proc.pid  # child re-opened; never the parent's fd
    journal.append({"type": "note", "who": "parent-2"})  # parent fh intact
    journal.close()
    notes = [r["who"] for r in Journal(path).records()
             if r.get("type") == "note"]
    assert notes == ["parent-1", "forked-child", "parent-2"]


# ------------------------------------------------- inline ≡ process runs

@pytest.mark.parametrize("shards", [2, 4, 8])
def test_process_equals_inline_terminal_states(tmp_path, shards):
    """Invariant 13 at 2/4/8 shards: identical workload (echo storms, Map
    fan-out, tenant-stamped metered runs) → identical terminal states."""
    inline = make_backend(
        "thread", fresh_registry(), num_shards=shards, clock=RealClock(),
        journal_path=str(tmp_path / "inline.jsonl"), admission_window=4,
    )
    try:
        ref = signature(submit_workload(inline))
    finally:
        inline.shutdown()

    proc = make_backend(
        "process", fresh_registry(), num_shards=shards,
        journal_path=str(tmp_path / "proc.jsonl"), admission_window=4,
        options={"registry_spec": REGISTRY_SPEC},
    )
    try:
        assert isinstance(proc, ProcessBackend)
        assert proc.backend_name == "process"
        got = signature(submit_workload(proc))
        # Map children count as runs too, so >= the top-level submissions
        assert proc.stats["runs_succeeded"] >= len(got)
    finally:
        proc.shutdown()

    assert got == ref
    assert all(status == RUN_SUCCEEDED for status, _, _ in ref.values())
    # the tenant stamp crossed the boundary on every metered run
    assert {rid for rid, (_, t, _) in got.items() if t == "acme"} \
        == {f"run-e{i:02d}" for i in range(0, 12, 3)}


# ------------------------------------------- SIGKILL mid-storm failover

def _storm(backend, n_runs: int) -> dict[str, object]:
    flow = asl.parse(CHAIN)
    handles = {}
    for i in range(n_runs):
        h = backend.start_run(flow, {"msg": f"m{i}"}, flow_id="chain",
                              run_id=f"run-{i:04d}")
        handles[h.run_id] = h
    return handles


def test_sigkill_midstorm_recovers_exactly_once(tmp_path):
    """SIGKILL one worker of a 4-shard process-backend storm: every run
    reaches the uninterrupted reference's terminal state, exactly once at
    the durability layer (one ``run_completed`` per run across all
    segments), under a bumped fencing epoch on the victim's segment."""
    n_runs = 32
    # uninterrupted reference: same topology, no chaos
    ref_backend = ProcessBackend(
        REGISTRY_SPEC, num_shards=4, num_workers=4,
        journal_path=str(tmp_path / "ref.jsonl"),
    )
    try:
        ref_handles = _storm(ref_backend, n_runs)
        for rid in ref_handles:
            assert ref_backend.wait(rid, WAIT_S).status == RUN_SUCCEEDED
        ref = {rid: (h.status, h.context["b"]["details"]["echo_string"])
               for rid, h in ref_handles.items()}
    finally:
        ref_backend.shutdown()

    chaos = ChaosPlane(seed=11, clock=RealClock())
    journal_base = str(tmp_path / "storm.jsonl")
    backend = ProcessBackend(
        REGISTRY_SPEC, num_shards=4, num_workers=4,
        journal_path=journal_base,
        heartbeat_interval=0.2, heartbeat_timeout=0.8, chaos=chaos,
    )
    try:
        # plan the kill only once the fleet is up: the plan stays a pure
        # keyed draw, the delivery is a real signal mid-flight
        plan = chaos.plan_kill(1, at=time.time() + 0.4, mode="sigkill")
        handles = _storm(backend, n_runs)
        for rid in handles:
            assert backend.wait(rid, WAIT_S).status == RUN_SUCCEEDED, rid
        deadline = time.time() + 30.0
        while not backend.failovers and time.time() < deadline:
            time.sleep(0.05)

        # the plan fired as a real SIGKILL and was detected + repaired
        assert plan.executed
        assert ("kill", "worker1", "sigkill") in chaos.timeline
        assert len(backend.failovers) == 1
        fo = backend.failovers[0]
        assert fo["worker"] == 1
        assert fo["shards"] == [1]  # num_workers == num_shards: 1:1 mapping
        assert fo["completed_at"] >= fo["detected_at"]
        assert fo["takeover_s"] < 30.0
        assert fo["runs_resumed"] + fo["terminal_resolved"] \
            + fo["resubmitted"] >= 0
        # the orphaned shard was re-homed onto a survivor
        assert backend.shard_owner(1) != 1

        got = {rid: (h.status, h.context["b"]["details"]["echo_string"])
               for rid, h in handles.items()}
        assert got == ref
    finally:
        backend.shutdown()

    # exactly-once at the durability layer: across all four segments every
    # run carries exactly one terminal record, and the victim's segment was
    # taken over under a bumped fencing epoch
    completed: dict[str, int] = {}
    epochs = {}
    for shard in range(4):
        journal = Journal(segment_path(journal_base, shard, 4))
        for rec in journal.records():
            if rec.get("type") == "run_completed":
                rid = rec.get("run_id") or rec.get("run")
                completed[rid] = completed.get(rid, 0) + 1
        epochs[shard] = replay_segment(journal).epoch
        journal.close()
    assert completed == {f"run-{i:04d}": 1 for i in range(n_runs)}
    assert epochs[1] >= 1  # takeover bumped the victim's epoch
    assert all(epochs[s] == 0 for s in (0, 2, 3))  # survivors undisturbed


def test_direct_kill_rehomes_and_reports_takeover(tmp_path):
    """fig_mttr-style takeover: kill a worker pid directly (no chaos) and
    read the failover timeline — detection, takeover latency, re-homing."""
    backend = ProcessBackend(
        REGISTRY_SPEC, num_shards=2, num_workers=2,
        journal_path=str(tmp_path / "mttr.jsonl"),
        heartbeat_interval=0.2, heartbeat_timeout=0.8,
    )
    try:
        flow = asl.parse(CHAIN)
        handles = {}
        for i in range(8):
            h = backend.start_run(flow, {"msg": f"m{i}"}, flow_id="chain",
                                  run_id=f"run-{i:04d}")
            handles[h.run_id] = h
        time.sleep(0.15)  # let submissions reach the workers
        os.kill(backend.worker_pid(1), signal.SIGKILL)
        for rid, h in handles.items():
            assert backend.wait(rid, WAIT_S).status == RUN_SUCCEEDED, rid
        deadline = time.time() + 30.0
        while not backend.failovers and time.time() < deadline:
            time.sleep(0.05)
        assert len(backend.failovers) == 1
        fo = backend.failovers[0]
        assert fo["worker"] == 1
        assert fo["shards"] == [1]
        assert fo["takeover_s"] >= 0.0
        assert backend.shard_owner(1) == 0  # survivor adopted the shard
        assert 1 in backend.dead_workers
    finally:
        backend.shutdown()
