import pytest

from repro.core import schema


def test_basic_types():
    s = {"type": "object", "properties": {"n": {"type": "integer"}},
         "required": ["n"]}
    assert schema.validate({"n": 3}, s) == {"n": 3}
    with pytest.raises(schema.ValidationFailure):
        schema.validate({"n": "x"}, s)
    with pytest.raises(schema.ValidationFailure):
        schema.validate({}, s)
    with pytest.raises(schema.ValidationFailure):
        schema.validate({"n": True}, s)  # bool is not integer


def test_defaults_applied():
    s = {"type": "object", "properties": {"k": {"type": "string", "default": "v"}}}
    assert schema.validate({}, s) == {"k": "v"}


def test_nested_and_arrays():
    s = {
        "type": "object",
        "properties": {
            "items": {
                "type": "array",
                "items": {"type": "object", "properties": {"id": {"type": "string"}},
                          "required": ["id"]},
                "minItems": 1,
            }
        },
        "required": ["items"],
    }
    schema.validate({"items": [{"id": "a"}]}, s)
    with pytest.raises(schema.ValidationFailure):
        schema.validate({"items": []}, s)
    with pytest.raises(schema.ValidationFailure):
        schema.validate({"items": [{}]}, s)


def test_enum_const_pattern_bounds():
    s = {
        "type": "object",
        "properties": {
            "mode": {"type": "string", "enum": ["a", "b"]},
            "k": {"const": 5},
            "name": {"type": "string", "pattern": "^[a-z]+$"},
            "x": {"type": "number", "minimum": 0, "maximum": 1},
        },
    }
    schema.validate({"mode": "a", "k": 5, "name": "ok", "x": 0.5}, s)
    for bad in ({"mode": "c"}, {"k": 6}, {"name": "NO"}, {"x": 2}):
        with pytest.raises(schema.ValidationFailure):
            schema.validate(bad, s)


def test_additional_properties_false():
    s = {"type": "object", "properties": {"a": {}}, "additionalProperties": False}
    schema.validate({"a": 1}, s)
    with pytest.raises(schema.ValidationFailure):
        schema.validate({"b": 1}, s)


def test_union_type_and_anyof():
    s = {"type": ["string", "number"]}
    schema.validate("x", s)
    schema.validate(1.5, s)
    with pytest.raises(schema.ValidationFailure):
        schema.validate([], s)
    s2 = {"anyOf": [{"type": "string"}, {"type": "integer"}]}
    schema.validate("x", s2)
    schema.validate(3, s2)
    with pytest.raises(schema.ValidationFailure):
        schema.validate(1.5, s2)


def test_ref_resolution():
    s = {
        "definitions": {"ep": {"type": "string", "minLength": 1}},
        "type": "object",
        "properties": {"src": {"$ref": "#/definitions/ep"}},
    }
    schema.validate({"src": "x"}, s)
    with pytest.raises(schema.ValidationFailure):
        schema.validate({"src": ""}, s)


def test_check_schema_rejects_malformed():
    for bad in (
        {"type": "nope"},
        {"properties": []},
        {"required": [1]},
        {"pattern": "["},
        {"anyOf": []},
    ):
        with pytest.raises(schema.SchemaError):
            schema.check_schema(bad)
