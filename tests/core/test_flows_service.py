"""FlowsService: publish/discover/invoke/manage, RBAC, auth delegation,
flow-as-action composition."""

import pytest

from repro.core.actions import ActionRegistry
from repro.core.auth import AuthService, Caller
from repro.core.clock import VirtualClock
from repro.core.engine import RUN_SUCCEEDED
from repro.core.errors import (
    FlowValidationError,
    Forbidden,
    InputValidationError,
    NotFound,
)
from repro.core.flows_service import FlowsService
from repro.core.providers import EchoProvider, SleepProvider

ECHO_FLOW = {
    "StartAt": "E",
    "States": {
        "E": {"Type": "Action", "ActionUrl": "ap://echo",
              "Parameters": {"echo_string.$": "$.msg"},
              "ResultPath": "$.echoed", "End": True}
    },
}
SCHEMA = {
    "type": "object",
    "properties": {"msg": {"type": "string"}},
    "required": ["msg"],
    "additionalProperties": True,
}


def make_service(with_auth=True):
    clock = VirtualClock()
    auth = AuthService() if with_auth else None
    registry = ActionRegistry()
    registry.register(EchoProvider(clock=clock, auth=auth))
    registry.register(SleepProvider(clock=clock, auth=auth))
    svc = FlowsService(registry, clock=clock, auth=auth)
    return svc, auth, clock


def caller_for(auth, svc, username, flow_record):
    """Consent + token acquisition for running a flow (the OAuth dance)."""
    auth.create_identity(username)
    auth.grant_consent(username, flow_record.scope)
    token = auth.issue_token(username, flow_record.scope)
    return Caller(identity=auth.get_identity(username),
                  tokens={flow_record.scope: token})


def test_publish_validates():
    svc, auth, _ = make_service()
    with pytest.raises(FlowValidationError):
        svc.publish_flow({"StartAt": "X", "States": {}})
    with pytest.raises(FlowValidationError):
        svc.publish_flow(ECHO_FLOW, input_schema={"type": "nope"})
    from repro.core.errors import ActionUnknown

    with pytest.raises(ActionUnknown):
        svc.publish_flow(
            {"StartAt": "E",
             "States": {"E": {"Type": "Action", "ActionUrl": "ap://missing",
                               "End": True}}}
        )


def test_publish_registers_dependent_scopes():
    svc, auth, _ = make_service()
    record = svc.publish_flow(ECHO_FLOW, input_schema=SCHEMA, owner="alice",
                              title="Echo flow")
    scope = auth.get_scope(record.scope)
    assert scope.dependent_scopes == ["urn:repro:scopes:echo:run"]


def test_run_flow_end_to_end_with_delegation():
    svc, auth, clock = make_service()
    record = svc.publish_flow(
        ECHO_FLOW, input_schema=SCHEMA, owner="alice",
        starters=["all_authenticated_users"],
    )
    caller = caller_for(auth, svc, "bob", record)
    run = svc.run_flow(record.flow_id, {"msg": "hello"}, caller=caller)
    svc.engine.run_to_completion(run.run_id)
    assert run.status == RUN_SUCCEEDED
    assert run.context["echoed"]["details"]["echo_string"] == "hello"
    assert run.creator == "bob"


def test_input_schema_enforced():
    svc, auth, _ = make_service()
    record = svc.publish_flow(ECHO_FLOW, input_schema=SCHEMA, owner="alice",
                              starters=["all_authenticated_users"])
    caller = caller_for(auth, svc, "bob", record)
    with pytest.raises(InputValidationError):
        svc.run_flow(record.flow_id, {"msg": 42}, caller=caller)
    with pytest.raises(InputValidationError):
        svc.run_flow(record.flow_id, {}, caller=caller)


def test_starter_role_enforced():
    svc, auth, _ = make_service()
    record = svc.publish_flow(ECHO_FLOW, input_schema=SCHEMA, owner="alice",
                              starters=["user:carol"])
    caller = caller_for(auth, svc, "bob", record)
    with pytest.raises(Forbidden):
        svc.run_flow(record.flow_id, {"msg": "x"}, caller=caller)


def test_missing_token_rejected():
    svc, auth, _ = make_service()
    record = svc.publish_flow(ECHO_FLOW, input_schema=SCHEMA, owner="alice",
                              starters=["all_authenticated_users"])
    auth.create_identity("bob")
    bare = Caller(identity=auth.get_identity("bob"))
    with pytest.raises(InputValidationError):
        svc.run_flow(record.flow_id, {"msg": "x"}, caller=bare)


def test_visibility_and_search():
    svc, auth, _ = make_service()
    svc.publish_flow(ECHO_FLOW, input_schema=SCHEMA, owner="alice",
                     title="SSX analysis", keywords=["aps", "ssx"],
                     viewers=["public"])
    svc.publish_flow(ECHO_FLOW, input_schema=SCHEMA, owner="alice",
                     title="Private flow", viewers=["user:alice"])
    auth.create_identity("eve")
    eve = Caller(identity=auth.get_identity("eve"))
    visible = svc.search_flows(caller=eve)
    assert [r.title for r in visible] == ["SSX analysis"]
    assert svc.search_flows("ssx", caller=eve)[0].title == "SSX analysis"
    alice = Caller(identity=auth.create_identity("alice"))
    assert len(svc.search_flows(caller=alice)) == 2


def test_update_and_remove_roles():
    svc, auth, _ = make_service()
    record = svc.publish_flow(ECHO_FLOW, input_schema=SCHEMA, owner="alice",
                              administrators=["user:adm"])
    auth.create_identity("adm")
    auth.create_identity("alice")
    auth.create_identity("bob")
    adm = Caller(identity=auth.get_identity("adm"))
    bob = Caller(identity=auth.get_identity("bob"))
    alice = Caller(identity=auth.get_identity("alice"))
    svc.update_flow(record.flow_id, caller=adm, title="New title")
    assert record.title == "New title"
    with pytest.raises(Forbidden):
        svc.update_flow(record.flow_id, caller=bob, title="X")
    # only the owner may remove (admins may not)
    with pytest.raises(Forbidden):
        svc.remove_flow(record.flow_id, caller=adm)
    svc.remove_flow(record.flow_id, caller=alice)
    with pytest.raises(NotFound):
        svc.get_flow(record.flow_id)


def test_run_monitor_manager_roles():
    svc, auth, clock = make_service()
    record = svc.publish_flow(
        {"StartAt": "S",
         "States": {"S": {"Type": "Action", "ActionUrl": "ap://sleep",
                           "Parameters": {"seconds": 1000.0}, "End": True}}},
        owner="alice", starters=["all_authenticated_users"],
    )
    caller = caller_for(auth, svc, "bob", record)
    auth.create_identity("watcher")
    auth.create_identity("boss")
    auth.create_identity("rando")
    run = svc.run_flow(record.flow_id, {}, caller=caller,
                       monitor_by=["user:watcher"], manage_by=["user:boss"])
    svc.engine.scheduler.drain(until=5.0)
    watcher = Caller(identity=auth.get_identity("watcher"))
    boss = Caller(identity=auth.get_identity("boss"))
    rando = Caller(identity=auth.get_identity("rando"))
    assert svc.run_status(run.run_id, caller=watcher)["status"] == "ACTIVE"
    assert len(svc.run_events(run.run_id, caller=watcher)) >= 2
    with pytest.raises(Forbidden):
        svc.run_status(run.run_id, caller=rando)
    with pytest.raises(Forbidden):
        svc.cancel_run(run.run_id, caller=watcher)  # monitor may not cancel
    svc.cancel_run(run.run_id, caller=boss)
    svc.engine.run_to_completion(run.run_id, until=10.0)
    assert run.status == "CANCELLED"


def test_flow_invokes_flow_as_action():
    svc, auth, clock = make_service()
    child = svc.publish_flow(ECHO_FLOW, input_schema=SCHEMA, owner="alice",
                             starters=["all_authenticated_users"],
                             flow_id="child-flow")
    parent_def = {
        "StartAt": "RunChild",
        "States": {
            "RunChild": {"Type": "Action", "ActionUrl": "flow://child-flow",
                          "Parameters": {"msg.$": "$.outer_msg"},
                          "ResultPath": "$.child", "End": True}
        },
    }
    parent = svc.publish_flow(parent_def, owner="alice",
                              starters=["all_authenticated_users"],
                              flow_id="parent-flow")
    # parent's scope depends on the child flow's scope
    assert auth.get_scope(parent.scope).dependent_scopes == [child.scope]
    caller = caller_for(auth, svc, "bob", parent)
    run = svc.run_flow(parent.flow_id, {"outer_msg": "nested!"}, caller=caller)
    svc.engine.run_to_completion(run.run_id)
    assert run.status == RUN_SUCCEEDED
    child_out = run.context["child"]["details"]["output"]
    assert child_out["echoed"]["details"]["echo_string"] == "nested!"


def test_list_runs_filtering():
    svc, auth, _ = make_service()
    record = svc.publish_flow(ECHO_FLOW, input_schema=SCHEMA, owner="alice",
                              starters=["all_authenticated_users"])
    caller = caller_for(auth, svc, "bob", record)
    r1 = svc.run_flow(record.flow_id, {"msg": "a"}, caller=caller,
                      tags=["expA"])
    r2 = svc.run_flow(record.flow_id, {"msg": "b"}, caller=caller,
                      tags=["expB"])
    svc.engine.scheduler.drain()
    runs = svc.list_runs(caller=caller, tag="expA")
    assert [r["run_id"] for r in runs] == [r1.run_id]
    runs = svc.list_runs(caller=caller, status="SUCCEEDED")
    assert {r["run_id"] for r in runs} == {r1.run_id, r2.run_id}
