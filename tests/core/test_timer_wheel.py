"""Differential tests: hierarchical timer wheel vs a flat-heap reference.

The wheel (``repro.core.timer_wheel``) replaces the scheduler's flat heap,
so its *only* license to exist is byte-identical behaviour: every pop comes
out in ``(due time, insertion seq)`` order — time, then insertion order —
exactly like ``heapq`` over ``(t, seq)`` tuples, and ``next_deadline()`` is
exact (the true earliest pending due time, never a bucket lower bound).
These properties are what keep the PoolScheduler's deterministic
VirtualClock merge unchanged across the swap.

Random schedules exercise the wheel's interesting geometry: entries inside
one tick (straight to the imminent heap), entries spanning bucket and level
boundaries, far-future deadlines beyond the top level's width, simultaneous
deadlines (tie-broken by insertion seq — including ties landing exactly on
a bucket's start time, the cascade's strict-vs-non-strict comparison edge),
cancellations (lazily reaped), and interleaved cursor advances.

Uses the ``repro.testing`` hypothesis shim: the real hypothesis when
installed, a deterministic seeded sweep otherwise.
"""

import heapq

import pytest

from repro.core.clock import VirtualClock
from repro.core.engine import Scheduler
from repro.core.timer_wheel import TimerWheel
from repro.testing import hypothesis_shim

given, settings, st = hypothesis_shim()

pytestmark = pytest.mark.slow


class FlatHeapModel:
    """The pre-wheel scheduler storage: one heapq of (t, seq) entries."""

    def __init__(self):
        self._heap = []
        self._seq = 0
        self._cancelled = set()

    def schedule(self, t):
        self._seq += 1
        heapq.heappush(self._heap, (float(t), self._seq))
        return self._seq

    def cancel(self, seq):
        self._cancelled.add(seq)

    def next_deadline(self):
        while self._heap and self._heap[0][1] in self._cancelled:
            heapq.heappop(self._heap)
        return self._heap[0][0] if self._heap else None

    def pop(self, until=None):
        deadline = self.next_deadline()
        if deadline is None or (until is not None and deadline > until):
            return None
        return heapq.heappop(self._heap)  # (t, seq)

    def __len__(self):
        n = 0
        for t, seq in self._heap:
            if seq not in self._cancelled:
                n += 1
        return n


# Op stream over both structures.  Delays are quantized to .25 so
# simultaneous deadlines are common, and the mix spans every wheel level
# for tick=0.5/span=4/levels=3 (level widths 0.5, 2.0, 8.0 — delays up to
# 200 overflow the top level's width, exercising the unbounded dict
# indexing).
OPS = st.lists(
    st.one_of(
        st.tuples(st.just("schedule"), st.integers(0, 800)),   # delay/4
        st.tuples(st.just("schedule_past"), st.integers(0, 40)),
        st.tuples(st.just("cancel"), st.integers(0, 10**6)),
        st.tuples(st.just("advance"), st.integers(1, 120)),    # delta/4
        st.tuples(st.just("pop_until"), st.integers(0, 200)),  # horizon/4
        st.tuples(st.just("pop_all_due"), st.just(0)),
        st.tuples(st.just("peek"), st.just(0)),
    ),
    max_size=80,
)


def _run_differential(ops, tick, span, levels):
    wheel = TimerWheel(now=0.0, tick=tick, span=span, levels=levels)
    model = FlatHeapModel()
    handles = {}  # model seq -> wheel handle
    now = 0.0
    for op, arg in ops:
        if op == "schedule":
            t = now + arg / 4.0
            seq = model.schedule(t)
            handles[seq] = wheel.schedule(t, fn=lambda: None)
        elif op == "schedule_past":
            # entries behind the cursor must fire immediately, in order
            t = max(0.0, now - arg / 4.0)
            seq = model.schedule(t)
            handles[seq] = wheel.schedule(t, fn=lambda: None)
        elif op == "cancel":
            live = [s for s in handles if not handles[s].cancelled]
            if live:
                seq = live[arg % len(live)]
                model.cancel(seq)
                assert wheel.cancel(handles[seq]) is True
        elif op == "advance":
            now += arg / 4.0
            wheel.advance_to(now)
        elif op == "pop_until":
            until = now + arg / 4.0
            while True:
                got = wheel.pop(until=until)
                want = model.pop(until=until)
                if want is None:
                    assert got is None
                    break
                assert got is not None, f"wheel dropped {want}"
                assert (got.t, got.seq) == want, (
                    f"pop order diverged: wheel {(got.t, got.seq)} "
                    f"vs flat heap {want}"
                )
                handles.pop(got.seq)  # fired: no longer cancellable
                now = max(now, got.t)
        elif op == "pop_all_due":
            while True:
                got = wheel.pop(until=now)
                want = model.pop(until=now)
                if want is None:
                    assert got is None
                    break
                assert got is not None and (got.t, got.seq) == want
                handles.pop(got.seq)
        elif op == "peek":
            assert wheel.next_deadline() == model.next_deadline(), (
                "next_deadline must be exact, not a bucket lower bound"
            )
        assert len(wheel) == len(model)
    # drain: the full residue must come out in identical order
    while True:
        got = wheel.pop()
        want = model.pop()
        if want is None:
            assert got is None
            break
        assert got is not None and (got.t, got.seq) == want
    assert len(wheel) == 0


@settings(max_examples=40)
@given(OPS)
def test_wheel_matches_flat_heap_small_geometry(ops):
    """Tiny levels force constant cascading — the worst case for ordering."""
    _run_differential(ops, tick=0.5, span=4, levels=3)


@settings(max_examples=25)
@given(OPS)
def test_wheel_matches_flat_heap_default_geometry(ops):
    """The scheduler's production geometry (wide buckets, rare cascades)."""
    _run_differential(ops, tick=1.0, span=256, levels=4)


def test_simultaneous_deadlines_pop_in_insertion_order():
    wheel = TimerWheel(tick=1.0, span=4, levels=3)
    # all land exactly on a level-1 bucket start: the tie edge where a
    # non-strict cascade comparison would leave heap entries popping ahead
    # of equal-time bucket entries with smaller seqs
    t = 16.0
    first = wheel.schedule(t, fn=lambda: None)
    wheel.advance_to(15.5)  # t is now < one level-1 width away: cascades
    second = wheel.schedule(t, fn=lambda: None)
    third = wheel.schedule(t + 0.0, fn=lambda: None)
    order = []
    while True:
        handle = wheel.pop()
        if handle is None:
            break
        order.append(handle.seq)
    assert order == [first.seq, second.seq, third.seq]


def test_far_future_deadline_beyond_top_level():
    wheel = TimerWheel(tick=1.0, span=4, levels=2)  # top width = 4s
    near = wheel.schedule(2.0, fn=lambda: None)
    far = wheel.schedule(3 * 7 * 24 * 3600.0, fn=lambda: None)  # three weeks
    assert wheel.next_deadline() == 2.0
    assert wheel.pop() is near
    assert wheel.next_deadline() == far.t
    assert wheel.pop(until=100.0) is None  # horizon respected
    assert wheel.pop() is far
    assert wheel.pop() is None


def test_cancel_is_lazy_but_invisible():
    wheel = TimerWheel(tick=1.0, span=4, levels=2)
    a = wheel.schedule(5.0, fn=lambda: None)
    b = wheel.schedule(5.0, fn=lambda: None)
    c = wheel.schedule(9.0, fn=lambda: None)
    assert wheel.cancel(a) is True
    assert wheel.cancel(a) is False  # second cancel is a no-op
    assert len(wheel) == 2
    assert wheel.next_deadline() == 5.0
    assert wheel.pop() is b
    assert wheel.pop() is c
    assert wheel.pop() is None


def test_cancel_after_fire_is_a_noop():
    """Cancelling a handle that already popped must not corrupt the live
    count (the Scheduler promises False for already-fired handles)."""
    wheel = TimerWheel(tick=1.0, span=4, levels=2)
    fired = wheel.schedule(1.0, fn=lambda: None)
    pending = wheel.schedule(10.0, fn=lambda: None)
    assert wheel.pop() is fired
    assert wheel.cancel(fired) is False
    assert len(wheel) == 1
    assert wheel.cancel(pending) is True
    assert len(wheel) == 0
    assert wheel.pop() is None


def test_dormant_entries_cost_no_cascades_until_imminent():
    """The O(live) claim: parked far-future entries sit untouched."""
    wheel = TimerWheel(tick=1.0, span=256, levels=4)
    for i in range(1000):
        wheel.schedule(1e6 + i, fn=lambda: None)
    now = 0.0
    for _ in range(100):
        handle = wheel.schedule(now + 2.0, fn=lambda: None)
        assert wheel.pop(until=now + 3.0) is handle
        now = handle.t
    # each near-term entry cascades level 0 -> imminent exactly once;
    # the churn never touched the dormant cohort's coarse bucket
    assert wheel.cascades == 100
    assert len(wheel) == 1000


def test_scheduler_drain_is_deterministic_over_the_wheel():
    """End-to-end: two identical schedules drain in the identical order."""

    def build():
        clock = VirtualClock()
        sched = Scheduler(clock)
        fired = []
        for i, delay in enumerate([5.0, 1.0, 5.0, 0.0, 3600.0, 5.0, 1.0]):
            sched.call_later(delay, lambda i=i: fired.append((clock.now(), i)))
        handle = sched.call_later(2.0, lambda: fired.append("cancelled"))
        sched.cancel(handle)
        sched.drain(until=7200.0)
        return fired

    first, second = build(), build()
    assert first == second
    assert "cancelled" not in first
    assert [i for _, i in first] == [3, 1, 6, 0, 2, 5, 4]
    assert [t for t, _ in first] == sorted(t for t, _ in first)
