import pytest

from repro.core.auth import AuthService, Caller
from repro.testing import hypothesis_shim

# real hypothesis when installed; deterministic seeded sweep otherwise
given, settings, st = hypothesis_shim()
from repro.core.clock import VirtualClock
from repro.core.errors import Forbidden, QueueInvariantError
from repro.core.queues import QueueService


def make_service():
    clock = VirtualClock()
    return QueueService(clock=clock), clock


def test_send_receive_ack_order():
    svc, _ = make_service()
    q = svc.create_queue("events")
    ids = [svc.send(q.queue_id, {"n": i}) for i in range(5)]
    got = svc.receive(q.queue_id, max_messages=10)
    assert [m["body"]["n"] for m in got] == list(range(5))
    assert [m["message_id"] for m in got] == ids
    for m in got:
        svc.ack(q.queue_id, m["receipt"])
    assert svc.depth(q.queue_id) == 0


def test_visibility_timeout_redelivery():
    svc, clock = make_service()
    q = svc.create_queue("events", visibility_timeout=10.0)
    svc.send(q.queue_id, {"n": 1})
    [m1] = svc.receive(q.queue_id)
    # invisible while the receipt is outstanding
    assert svc.receive(q.queue_id) == []
    clock.advance(11.0)
    [m2] = svc.receive(q.queue_id)  # redelivered
    assert m2["body"] == {"n": 1}
    assert m2["receive_count"] == 2
    # the stale receipt can no longer ack
    with pytest.raises(QueueInvariantError):
        svc.ack(q.queue_id, m1["receipt"])
    svc.ack(q.queue_id, m2["receipt"])
    assert svc.depth(q.queue_id) == 0


def test_explicit_zero_visibility_timeout_is_not_queue_default():
    """Regression: ``visibility_timeout=0`` was coerced to the queue default
    by a falsy ``or`` — an explicit 0 must mean "no invisibility window"."""
    svc, _ = make_service()
    q = svc.create_queue("events", visibility_timeout=30.0)
    svc.send(q.queue_id, {"n": 1})
    [m1] = svc.receive(q.queue_id, visibility_timeout=0)
    # no invisibility window: immediately redeliverable (the default would
    # have hidden it for 30 virtual seconds)
    [m2] = svc.receive(q.queue_id, visibility_timeout=0)
    assert m2["message_id"] == m1["message_id"]
    assert m2["receive_count"] == 2
    # a zero-timeout receipt is expired on arrival; ack must say so rather
    # than silently dropping a message another receiver may now hold
    with pytest.raises(QueueInvariantError):
        svc.ack(q.queue_id, m2["receipt"])


def test_subsecond_visibility_timeout_override():
    svc, clock = make_service()
    q = svc.create_queue("events", visibility_timeout=30.0)
    svc.send(q.queue_id, {"n": 1})
    [m1] = svc.receive(q.queue_id, visibility_timeout=0.25)
    assert svc.receive(q.queue_id) == []  # still invisible
    clock.advance(0.3)
    [m2] = svc.receive(q.queue_id)  # redelivered after 0.25s, not 30s
    assert m2["message_id"] == m1["message_id"]
    svc.ack(q.queue_id, m2["receipt"])
    assert svc.depth(q.queue_id) == 0


def test_update_queue_accepts_zero_visibility_timeout():
    """``update_queue`` keys off presence (``key in updates``), so an
    explicit 0 must round-trip instead of being dropped as falsy."""
    svc, _ = make_service()
    q = svc.create_queue("events", visibility_timeout=30.0)
    svc.update_queue(q.queue_id, visibility_timeout=0.0)
    assert q.visibility_timeout == 0.0
    svc.send(q.queue_id, {"n": 1})
    [m1] = svc.receive(q.queue_id)  # queue default is now 0
    [m2] = svc.receive(q.queue_id)
    assert m2["message_id"] == m1["message_id"]
    assert m2["receive_count"] == 2


def test_deferred_delivery():
    svc, clock = make_service()
    q = svc.create_queue("later")
    svc.send(q.queue_id, {"n": 1}, delay=100.0)
    assert svc.receive(q.queue_id) == []
    clock.advance(101.0)
    [m] = svc.receive(q.queue_id)
    assert m["body"] == {"n": 1}


def test_in_order_blocks_behind_deferred():
    svc, clock = make_service()
    q = svc.create_queue("fifo")
    svc.send(q.queue_id, {"n": 1}, delay=50.0)
    svc.send(q.queue_id, {"n": 2})
    # in-order: message 2 is not delivered before message 1 is deliverable
    assert svc.receive(q.queue_id, max_messages=10) == []
    clock.advance(51.0)
    got = svc.receive(q.queue_id, max_messages=10)
    assert [m["body"]["n"] for m in got] == [1, 2]


def test_double_ack_rejected():
    svc, _ = make_service()
    q = svc.create_queue("x")
    svc.send(q.queue_id, 1)
    [m] = svc.receive(q.queue_id)
    svc.ack(q.queue_id, m["receipt"])
    with pytest.raises(QueueInvariantError):
        svc.ack(q.queue_id, m["receipt"])


def test_roles_enforced():
    clock = VirtualClock()
    auth = AuthService()
    alice = Caller(identity=auth.create_identity("alice"))
    bob = Caller(identity=auth.create_identity("bob"))
    svc = QueueService(clock=clock, auth=auth)
    q = svc.create_queue(
        "secure",
        admins=["user:alice"],
        senders=["user:alice"],
        receivers=["user:bob"],
        caller=alice,
    )
    svc.send(q.queue_id, {"ok": 1}, caller=alice)
    with pytest.raises(Forbidden):
        svc.send(q.queue_id, {"no": 1}, caller=bob)
    [m] = svc.receive(q.queue_id, caller=bob)
    with pytest.raises(Forbidden):
        svc.receive(q.queue_id, caller=alice)
    svc.ack(q.queue_id, m["receipt"], caller=bob)
    with pytest.raises(Forbidden):
        svc.delete_queue(q.queue_id, caller=bob)
    svc.delete_queue(q.queue_id, caller=alice)


def test_persistence_roundtrip(tmp_path):
    path = str(tmp_path / "queues.json")
    clock = VirtualClock()
    svc = QueueService(clock=clock, persist_path=path)
    q = svc.create_queue("durable")
    svc.send(q.queue_id, {"n": 1})
    svc.send(q.queue_id, {"n": 2})
    [m] = svc.receive(q.queue_id)
    svc.ack(q.queue_id, m["receipt"])
    # "restart"
    svc2 = QueueService(clock=VirtualClock(), persist_path=path)
    got = svc2.receive(q.queue_id, max_messages=10)
    assert [m["body"]["n"] for m in got] == [2]


@settings(max_examples=40, deadline=None)
@given(
    ops=st.lists(
        st.one_of(
            st.tuples(st.just("send"), st.integers(0, 99)),
            st.tuples(st.just("receive"), st.just(0)),
            st.tuples(st.just("ack"), st.just(0)),
            st.tuples(st.just("advance"), st.integers(1, 40)),
        ),
        max_size=60,
    )
)
def test_at_least_once_in_order_property(ops):
    """Under arbitrary receive/ack/timeout interleavings: every sent message
    is eventually delivered (at least once), acked messages never reappear,
    and first deliveries happen in send order."""
    svc, clock = make_service()
    q = svc.create_queue("prop", visibility_timeout=20.0)
    sent = []
    outstanding = []  # receipts not yet acked
    first_delivery_order = []
    acked = set()
    seen = set()
    for op, arg in ops:
        if op == "send":
            svc.send(q.queue_id, {"n": len(sent)})
            sent.append(len(sent))
        elif op == "receive":
            for m in svc.receive(q.queue_id, max_messages=3):
                n = m["body"]["n"]
                assert n not in acked, "acked message redelivered"
                if n not in seen:
                    seen.add(n)
                    first_delivery_order.append(n)
                outstanding.append((m["receipt"], n))
        elif op == "ack" and outstanding:
            receipt, n = outstanding.pop(0)
            try:
                svc.ack(q.queue_id, receipt)
                acked.add(n)
            except QueueInvariantError:
                pass  # receipt expired; message will be redelivered
        elif op == "advance":
            clock.advance(float(arg))
    # drain: all unacked messages must still be deliverable
    clock.advance(1000.0)
    while True:
        got = svc.receive(q.queue_id, max_messages=10)
        if not got:
            break
        for m in got:
            n = m["body"]["n"]
            assert n not in acked
            if n not in seen:
                seen.add(n)
                first_delivery_order.append(n)
            svc.ack(q.queue_id, m["receipt"])
            acked.add(n)
    assert seen == set(sent), "every sent message must be delivered"
    assert first_delivery_order == sorted(first_delivery_order), "in-order"
