"""Quickstart: author, publish, and run a flow in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py

A three-state flow — transfer a file, analyze it with a registered function,
catalog the result — runs under a deterministic virtual clock.
"""

import os
import tempfile

from repro.core import FlowsService, VirtualClock
from repro.core.actions import ActionRegistry
from repro.core.engine import PollingPolicy
from repro.core.providers import ComputeProvider, SearchProvider, TransferProvider

# --- set up the services ---------------------------------------------------
clock = VirtualClock()
workdir = tempfile.mkdtemp(prefix="quickstart-")
registry = ActionRegistry()
transfer = TransferProvider(clock=clock, workspace=workdir)
transfer.create_endpoint("instrument")
transfer.create_endpoint("cluster")
compute = ComputeProvider(clock=clock)
search = SearchProvider(clock=clock)
registry.register(transfer)
registry.register(compute)
registry.register(search)
flows = FlowsService(registry, clock=clock,
                     polling=PollingPolicy(use_callbacks=True))

# --- a dataset appears at the instrument ------------------------------------
with open(os.path.join(workdir, "instrument", "sample.dat"), "wb") as fh:
    fh.write(bytes(range(256)) * 64)

# --- register the analysis function (the funcX pattern) ---------------------
eid = compute.register_endpoint("cluster-ep")
fid = compute.register_function(
    lambda path: {"checksum": sum(open(
        transfer.endpoint("cluster").path(path), "rb").read()) % 65521},
    name="checksum",
)

# --- author + publish the flow ----------------------------------------------
definition = {
    "StartAt": "Stage",
    "States": {
        "Stage": {
            "Type": "Action", "ActionUrl": "ap://transfer",
            "Parameters": {
                "source_endpoint": "instrument", "destination_endpoint":
                "cluster", "source_path.$": "$.file",
                "destination_path.$": "$.file",
            },
            "ResultPath": "$.staged", "Next": "Analyze",
        },
        "Analyze": {
            "Type": "Action", "ActionUrl": "ap://compute",
            "Parameters": {"endpoint_id": eid, "function_id": fid,
                            "kwargs": {"path.$": "$.file"}},
            "ResultPath": "$.analysis", "Next": "Catalog",
        },
        "Catalog": {
            "Type": "Action", "ActionUrl": "ap://search",
            "Parameters": {"operation": "ingest", "index": "quickstart",
                            "subject.$": "$.file",
                            "entry.$": "$.analysis.details.results[0]"},
            "ResultPath": "$.cataloged", "End": True,
        },
    },
}
record = flows.publish_flow(
    definition,
    input_schema={"type": "object", "properties": {"file": {"type": "string"}},
                  "required": ["file"]},
    title="Quickstart analysis flow",
)

# --- run it ------------------------------------------------------------------
run = flows.run_flow(record.flow_id, {"file": "sample.dat"}, label="demo")
flows.engine.run_to_completion(run.run_id)

print(f"run {run.run_id}: {run.status} in {run.completion_time:.2f} virtual s")
for event in run.events:
    print(f"  t={event['time']:7.2f}  {event['code']:<16} "
          f"{event['details'].get('state', '')}")
print("catalog entry:", search.entries("quickstart")["sample.dat"]["entry"])
assert run.status == "SUCCEEDED"
