"""Elastic training: device failure -> catch -> restore onto a SMALLER mesh.

The headline fault-tolerance scenario for large fleets: a training job on an
N-device mesh loses devices mid-run; the training flow catches the failure
and resumes from the latest checkpoint on a smaller mesh (elastic shrink),
with all parameter/optimizer state resharded at restore time.

This example runs with 4 simulated host devices (set before JAX imports):
train on a (2, 2) data x model mesh, inject a NodeFailure, reshard to
(1, 2) — "half the fleet is gone" — and train to completion.

    PYTHONPATH=src python examples/elastic_training.py
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import tempfile  # noqa: E402

from repro import configs  # noqa: E402
from repro.configs.base import TrainConfig  # noqa: E402
from repro.core import FlowsService, RealClock  # noqa: E402
from repro.core.actions import ActionRegistry  # noqa: E402
from repro.core.engine import PollingPolicy  # noqa: E402
from repro.core.providers import ComputeProvider  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402
from repro.train.fabric import TrainingFabric  # noqa: E402


def main():
    workdir = tempfile.mkdtemp(prefix="elastic-")
    cfg = configs.get("internlm2-1.8b", smoke=True)
    big_mesh = make_mesh((2, 2), ("data", "model"))
    small_mesh = make_mesh((1, 2), ("data", "model"))

    fabric = TrainingFabric(
        cfg,
        TrainConfig(total_steps=30, warmup_steps=2, learning_rate=1e-3),
        batch=4, seq_len=32,
        ckpt_dir=os.path.join(workdir, "ckpt"),
        mesh=big_mesh,
    )
    fabric.save_checkpoint()
    fabric.inject_failure_at = 6  # devices "die" during the second segment

    clock = RealClock()
    registry = ActionRegistry()
    compute = ComputeProvider(clock=clock)
    registry.register(compute)
    flows = FlowsService(
        registry, clock=clock,
        polling=PollingPolicy(initial_seconds=0.05, cap_seconds=0.5,
                              use_callbacks=True),
    )
    eid = compute.register_endpoint("pod")
    f_train = compute.register_function(
        lambda: fabric.train_steps(n_steps=5), name="train5")
    f_ckpt = compute.register_function(
        lambda: fabric.save_checkpoint(), name="ckpt")
    f_shrink = compute.register_function(
        lambda: fabric.reshard(small_mesh), name="shrink")

    definition = {
        "Comment": "Elastic training: failure -> reshard -> resume",
        "StartAt": "Train1",
        "States": {
            "Train1": {
                "Type": "Action", "ActionUrl": "ap://compute",
                "Parameters": {"endpoint_id": eid, "function_id": f_train,
                                "kwargs": {}},
                "ResultPath": "$.t1", "Next": "Ckpt1"},
            "Ckpt1": {
                "Type": "Action", "ActionUrl": "ap://compute",
                "Parameters": {"endpoint_id": eid, "function_id": f_ckpt,
                                "kwargs": {}},
                "ResultPath": "$.c1", "Next": "Train2"},
            "Train2": {
                "Type": "Action", "ActionUrl": "ap://compute",
                "Parameters": {"endpoint_id": eid, "function_id": f_train,
                                "kwargs": {}},
                "ResultPath": "$.t2",
                "Catch": [{"ErrorEquals": ["ActionFailedException"],
                            "ResultPath": "$.failure",
                            "Next": "ShrinkAndRestore"}],
                "Next": "Done"},
            "ShrinkAndRestore": {
                "Type": "Action", "ActionUrl": "ap://compute",
                "Parameters": {"endpoint_id": eid, "function_id": f_shrink,
                                "kwargs": {}},
                "ResultPath": "$.reshard", "Next": "Train2"},
            "Done": {"Type": "Succeed"},
        },
    }
    record = flows.publish_flow(definition, title="Elastic training")
    run = flows.run_flow(record.flow_id, {}, label="elastic-demo")
    flows.engine.wait(run.run_id, timeout=1200)

    print(f"run: {run.status}")
    assert run.status == "SUCCEEDED", run.error
    failure = run.context.get("failure")
    print("caught failure:", failure["Details"]["error"])
    reshard = run.context["reshard"]["details"]["results"][0]
    print(f"resharded: {reshard['old_mesh']} -> {reshard['new_mesh']}, "
          f"restored step {reshard['restored_step']}")
    print("loss history:",
          [(h["step"], round(h["loss"], 3)) for h in fabric.history])
    final_step = fabric.history[-1]["step"]
    assert final_step >= 10, "training must have resumed after reshard"
    assert fabric.mesh.devices.shape == (1, 2)
    print("Elastic training complete: survived device loss, "
          "resumed on half the mesh.")


if __name__ == "__main__":
    main()
