"""SSX serial-crystallography pipeline (paper §2.1.1).

Two flows, exactly as the paper describes:

* **per-image flow** (7 steps): transfer image -> DIALS stills processing ->
  extract hit metadata -> generate visualization -> transfer for publication
  -> ingest to the SSX catalog -> return results to the beamline;
* **structure flow**: PRIME post-refinement over accumulated hits -> a
  ``Map`` state archiving every hit image to the portal (the hit count is
  only known at run time — dynamic data-parallel fan-out with
  ``MaxConcurrency: 4``, per docs/asl.md) -> copy the structure back to
  the beamline.

A Trigger watches the instrument queue and starts the per-image flow per
detector frame; a second Trigger fires the structure flow once enough hits
accumulate.  Both ride the FlowsService's shared EventRouter (push-based
event fabric: detector sends wake the dispatcher immediately — no polling).
"DIALS" and "PRIME" are stand-in JAX computations over the real staged
bytes.

    PYTHONPATH=src python examples/ssx_pipeline.py [--images 24]
"""

import argparse
import os
import tempfile

import numpy as np

from repro.core import FlowsService, VirtualClock
from repro.core.actions import ActionRegistry
from repro.core.engine import PollingPolicy
from repro.core.providers import ComputeProvider, SearchProvider, TransferProvider
from repro.core.queues import QueueService
from repro.core.triggers import TriggerConfig


def main():
    parser = argparse.ArgumentParser()
    parser.add_argument("--images", type=int, default=24)
    parser.add_argument("--hits-needed", type=int, default=6)
    args = parser.parse_args()

    rng = np.random.default_rng(7)
    clock = VirtualClock()
    workdir = tempfile.mkdtemp(prefix="ssx-")
    registry = ActionRegistry()
    transfer = TransferProvider(clock=clock, workspace=workdir)
    beamline = transfer.create_endpoint("beamline", bandwidth_bps=37e6,
                                        latency_s=0.5)  # paper: 37 MB/s
    transfer.create_endpoint("hpc", latency_s=0.5)
    transfer.create_endpoint("portal", latency_s=0.5)
    compute = ComputeProvider(clock=clock)
    search = SearchProvider(clock=clock)
    registry.register(transfer)
    registry.register(compute)
    registry.register(search)

    import jax.numpy as jnp

    hits_accumulator: list[dict] = []

    def dials_stills(image: str):
        """Stand-in for DIALS: peak-count the staged image bytes."""
        data = np.frombuffer(
            open(transfer.endpoint("hpc").path(image), "rb").read(), np.uint8
        )
        peaks = int(jnp.sum(jnp.asarray(data.astype(np.float32)) > 250))
        hit = bool(peaks > 40)
        if hit:
            hits_accumulator.append({"image": image, "peaks": peaks})
        return {"image": image, "peaks": peaks, "hit": hit}

    def make_viz(image: str, peaks: int):
        out = transfer.endpoint("hpc").path(image + ".viz.png")
        with open(out, "wb") as fh:
            fh.write(b"PNG" + bytes([peaks % 256]) * 32)
        return {"viz": image + ".viz.png"}

    def prime_solve():
        """Stand-in for PRIME: 'solve' from accumulated hits."""
        arr = jnp.asarray([h["peaks"] for h in hits_accumulator], jnp.float32)
        structure = {"n_hits": len(hits_accumulator),
                     "unit_cell_score": float(jnp.mean(arr))}
        out = transfer.endpoint("hpc").path("structure.pdb")
        with open(out, "w") as fh:
            fh.write(str(structure))
        return structure

    eid = compute.register_endpoint("polaris")
    f_dials = compute.register_function(
        dials_stills, modeled_duration=lambda kw: float(rng.lognormal(2.2, 0.5)))
    f_viz = compute.register_function(
        make_viz, modeled_duration=lambda kw: 3.0)
    f_prime = compute.register_function(
        prime_solve, modeled_duration=lambda kw: 120.0)

    queues = QueueService(clock=clock)
    flows = FlowsService(registry, clock=clock,
                         polling=PollingPolicy(use_callbacks=True),
                         queues=queues)

    def compute_state(fid, kwargs):
        return {"Type": "Action", "ActionUrl": "ap://compute",
                "Parameters": {"endpoint_id": eid, "function_id": fid,
                                "kwargs": kwargs}}

    per_image = flows.publish_flow({
        "Comment": "SSX per-image flow (paper steps 1-7)",
        "StartAt": "TransferToHPC",
        "States": {
            "TransferToHPC": {
                "Type": "Action", "ActionUrl": "ap://transfer",
                "Parameters": {
                    "operation": "transfer", "source_endpoint": "beamline",
                    "destination_endpoint": "hpc",
                    "source_path.$": "$.image",
                    "destination_path.$": "$.image"},
                "ResultPath": "$.t1", "Next": "DIALS"},
            "DIALS": {**compute_state(f_dials, {"image.$": "$.image"}),
                       "ResultPath": "$.dials", "Next": "CheckHit"},
            "CheckHit": {
                "Type": "Choice",
                "Choices": [{"Variable": "$.dials.details.results[0].hit",
                              "BooleanEquals": True, "Next": "Visualize"}],
                "Default": "ReturnResults"},
            "Visualize": {**compute_state(
                f_viz, {"image.$": "$.image",
                        "peaks.$": "$.dials.details.results[0].peaks"}),
                "ResultPath": "$.viz", "Next": "PublishArtifacts"},
            "PublishArtifacts": {
                "Type": "Action", "ActionUrl": "ap://transfer",
                "Parameters": {
                    "operation": "transfer", "source_endpoint": "hpc",
                    "destination_endpoint": "portal",
                    "source_path.$": "$.viz.details.results[0].viz",
                    "destination_path.$": "$.viz.details.results[0].viz"},
                "ResultPath": "$.t2", "Next": "Ingest"},
            "Ingest": {
                "Type": "Action", "ActionUrl": "ap://search",
                "Parameters": {"operation": "ingest", "index": "ssx",
                                "subject.$": "$.image",
                                "entry.$": "$.dials.details.results[0]"},
                "ResultPath": "$.ingested", "Next": "ReturnResults"},
            "ReturnResults": {
                "Type": "Action", "ActionUrl": "ap://transfer",
                "Parameters": {"operation": "ls", "endpoint": "hpc",
                                "path": "/"},
                "ResultPath": "$.returned", "End": True},
        },
    }, title="SSX per-image")

    structure_flow = flows.publish_flow({
        "Comment": "SSX structure flow (PRIME + per-hit archive fan-out)",
        "StartAt": "PRIME",
        "States": {
            "PRIME": {**compute_state(f_prime, {}),
                       "ResultPath": "$.structure", "Next": "ArchiveHits"},
            # dynamic fan-out: one archive transfer per accumulated hit.
            # The hit list's size is only known when the flow starts — a
            # static Parallel could not express this (it was previously N
            # separate per-image publications); MaxConcurrency caps the
            # load on the portal endpoint.
            "ArchiveHits": {
                "Type": "Map",
                "ItemsPath": "$.hits",
                "MaxConcurrency": 4,
                "ItemSelector": {"image.$": "$.item"},
                "Iterator": {
                    "StartAt": "Archive",
                    "States": {
                        "Archive": {
                            "Type": "Action", "ActionUrl": "ap://transfer",
                            "Parameters": {
                                "operation": "transfer",
                                "source_endpoint": "hpc",
                                "destination_endpoint": "portal",
                                "source_path.$": "$.image",
                                "destination_path.$": "$.image"},
                            "ResultPath": "$.archived", "End": True},
                    },
                },
                "ResultPath": "$.archived_hits", "Next": "CopyBack"},
            "CopyBack": {
                "Type": "Action", "ActionUrl": "ap://transfer",
                "Parameters": {
                    "operation": "transfer", "source_endpoint": "hpc",
                    "destination_endpoint": "beamline",
                    "source_path": "structure.pdb",
                    "destination_path": "structure.pdb"},
                "ResultPath": "$.copied", "End": True},
        },
    }, title="SSX structure")

    # triggers: detector frames -> per-image flow; hit threshold -> PRIME.
    # Both live on the FlowsService's shared EventRouter: detector sends
    # wake the dispatcher at the frame's delivery time (push-first), and
    # each received batch is matched against every predicate in one pass.
    frames_q = queues.create_queue("detector-frames")
    hits_q = queues.create_queue("hit-counter")
    router = flows.router
    image_runs, structure_runs = [], []

    def run_image(body, caller):
        r = flows.run_flow(per_image.flow_id, body, label=body["image"])
        image_runs.append(r.run_id)
        r.completion_callbacks.append(
            lambda run_: queues.send(
                hits_q.queue_id, {"hits": len(hits_accumulator)})
        )
        return r.run_id

    def run_structure(body, caller):
        if structure_runs:          # solve once per accumulation window
            return structure_runs[0]
        # the run-time-sized hit list feeds the structure flow's Map state
        r = flows.run_flow(
            structure_flow.flow_id,
            {**body, "hits": [h["image"] for h in hits_accumulator]},
            label="solve",
        )
        structure_runs.append(r.run_id)
        return r.run_id

    t1 = router.create_trigger(TriggerConfig(
        queue_id=frames_q.queue_id,
        predicate='image.endswith(".cbf")',
        transform={"image": "image"},
        action_invoker=run_image))
    t2 = router.create_trigger(TriggerConfig(
        queue_id=hits_q.queue_id,
        predicate=f"hits >= {args.hits_needed}",
        transform={"n_hits": "hits"},
        action_invoker=run_structure))
    router.enable(t1.trigger_id)
    router.enable(t2.trigger_id)

    # the instrument: 10 Hz frame generation (paper rate), ~1.5 MB images
    for i in range(args.images):
        name = f"img_{i:04d}.cbf"
        with open(os.path.join(beamline.root, name), "wb") as fh:
            fh.write(rng.integers(0, 256, size=150_000, dtype=np.uint8)
                     .tobytes())
        queues.send(frames_q.queue_id, {"image": name}, delay=i * 0.1)

    flows.engine.scheduler.drain(until=100_000.0, max_events=5_000_000)

    done = sum(1 for rid in image_runs
               if flows.engine.get_run(rid).status == "SUCCEEDED")
    print(f"per-image runs: {done}/{len(image_runs)} succeeded")
    print(f"hits found: {len(hits_accumulator)}")
    print(f"catalog entries: {len(search.entries('ssx'))}")
    for rid in structure_runs:
        r = flows.engine.get_run(rid)
        print(f"structure run {rid}: {r.status} -> "
              f"{r.context.get('structure', {}).get('details')}")
        archived = r.context.get("archived_hits", [])
        print(f"hits archived to portal via Map fan-out: {len(archived)} "
              f"(peak concurrent transfers {r.map_peak_live})")
        assert r.status == "SUCCEEDED"
        assert len(archived) == len(r.context["hits"])
        assert r.map_peak_live <= 4  # the Map admission window held
        for slot in archived:
            assert slot["archived"]["status"] == "SUCCEEDED"
    assert done == len(image_runs) == args.images
    assert structure_runs, "structure flow should have been triggered"
    print("SSX pipeline complete.")


if __name__ == "__main__":
    main()
