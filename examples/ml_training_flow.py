"""ML training + edge deployment flow (paper §2.1.2, HEDM use case).

Four steps, exactly the paper's: (1) transfer experimental data from the
instrument to the compute facility; (2) process it with the analysis
package ("MIDAS" stand-in builds token shards); (3) train a model on HPC
with the REAL JAX training fabric (a reduced-config LM, real gradients,
real checkpoints); (4) transfer the trained model to the edge for inference
— then an inference smoke-check runs at the "edge".

    PYTHONPATH=src python examples/ml_training_flow.py [--steps 20]
"""

import argparse
import os
import tempfile

import numpy as np

from repro import configs
from repro.configs.base import TrainConfig
from repro.core import FlowsService, VirtualClock
from repro.core.actions import ActionRegistry
from repro.core.engine import PollingPolicy
from repro.core.providers import ComputeProvider, EmailProvider, TransferProvider
from repro.train.data import ShardedTokenFiles, write_token_shards
from repro.train.fabric import TrainingFabric


def main():
    parser = argparse.ArgumentParser()
    parser.add_argument("--arch", default="internlm2-1.8b")
    parser.add_argument("--steps", type=int, default=20)
    parser.add_argument("--batch", type=int, default=4)
    parser.add_argument("--seq-len", type=int, default=32)
    args = parser.parse_args()

    clock = VirtualClock()
    workdir = tempfile.mkdtemp(prefix="mlflow-")
    registry = ActionRegistry()
    transfer = TransferProvider(clock=clock, workspace=workdir)
    instrument = transfer.create_endpoint("instrument", bandwidth_bps=100e6)
    hpc = transfer.create_endpoint("hpc")
    edge = transfer.create_endpoint("edge", bandwidth_bps=10e6)
    compute = ComputeProvider(clock=clock)
    email = EmailProvider(clock=clock)
    registry.register(transfer)
    registry.register(compute)
    registry.register(email)

    # raw experimental data appears at the instrument
    raw_dir = os.path.join(instrument.root, "raw")
    cfg = configs.get(args.arch, smoke=True)
    write_token_shards(raw_dir, vocab=cfg.vocab_size, n_shards=3, rows=16,
                       seq_len=args.seq_len)

    # the training fabric (real JAX) reads shards staged to the HPC endpoint
    staged_dir = os.path.join(hpc.root, "raw")
    fabric = TrainingFabric(
        cfg,
        TrainConfig(total_steps=args.steps, warmup_steps=2,
                    learning_rate=1e-3),
        batch=args.batch, seq_len=args.seq_len,
        ckpt_dir=os.path.join(hpc.root, "ckpt"),
        data=ShardedTokenFiles(staged_dir, batch=args.batch,
                               seq_len=args.seq_len),
    )
    eid = compute.register_endpoint("hpc-gpu")

    def midas_process():
        files = sorted(os.listdir(staged_dir))
        return {"shards": len(files)}

    def train(n_steps: int):
        out = fabric.train_steps(n_steps=n_steps)
        fabric.save_checkpoint()
        return out

    def edge_infer():
        from repro.models.model import Model
        from repro.serve.engine import ServeEngine

        engine = ServeEngine(Model(cfg), fabric.state.params, max_len=64)
        prompts = np.zeros((2, 8), np.int32)
        out = engine.generate(prompts, max_new_tokens=4)
        return {"generated_shape": list(out["tokens"].shape)}

    fns = {
        "midas": compute.register_function(
            midas_process, modeled_duration=lambda kw: 60.0),
        "train": compute.register_function(
            train, modeled_duration=lambda kw: 1800.0),
        "infer": compute.register_function(edge_infer),
    }

    flows = FlowsService(registry, clock=clock,
                         polling=PollingPolicy(use_callbacks=True))
    record = flows.publish_flow({
        "Comment": "HEDM ML training + edge deployment (paper §2.1.2)",
        "StartAt": "TransferData",
        "States": {
            "TransferData": {
                "Type": "Action", "ActionUrl": "ap://transfer",
                "Parameters": {
                    "operation": "transfer", "source_endpoint": "instrument",
                    "destination_endpoint": "hpc",
                    "source_path": "raw", "destination_path": "raw"},
                "ResultPath": "$.staged", "Next": "MIDAS"},
            "MIDAS": {
                "Type": "Action", "ActionUrl": "ap://compute",
                "Parameters": {"endpoint_id": eid,
                                "function_id": fns["midas"], "kwargs": {}},
                "ResultPath": "$.midas", "Next": "TrainModel"},
            "TrainModel": {
                "Type": "Action", "ActionUrl": "ap://compute",
                "Parameters": {"endpoint_id": eid,
                                "function_id": fns["train"],
                                "kwargs": {"n_steps.$": "$.steps"}},
                "ResultPath": "$.train", "WaitTime": 86400,
                "Next": "DeployToEdge"},
            "DeployToEdge": {
                "Type": "Action", "ActionUrl": "ap://transfer",
                "Parameters": {
                    "operation": "transfer", "source_endpoint": "hpc",
                    "destination_endpoint": "edge",
                    "source_path": "ckpt", "destination_path": "model"},
                "ResultPath": "$.deployed", "Next": "EdgeCheck"},
            "EdgeCheck": {
                "Type": "Action", "ActionUrl": "ap://compute",
                "Parameters": {"endpoint_id": eid,
                                "function_id": fns["infer"], "kwargs": {}},
                "ResultPath": "$.inference", "Next": "Notify"},
            "Notify": {
                "Type": "Action", "ActionUrl": "ap://email",
                "Parameters": {
                    "to": "beamline@aps.example",
                    "subject": "Model deployed to edge",
                    "body": "Training loss ${loss}",
                    "template_values.$": "$.notify"},
                "ResultPath": "$.notified", "End": True},
        },
    }, title="HEDM ML training flow")

    run = flows.run_flow(
        record.flow_id,
        {"steps": args.steps, "notify": {"loss": "(see details)"}},
        label="hedm-ml",
    )
    flows.engine.run_to_completion(run.run_id)
    print(f"run: {run.status} at virtual t={run.completion_time:.0f}s")
    assert run.status == "SUCCEEDED", run.error
    train_result = run.context["train"]["details"]["results"][0]
    print(f"trained to step {train_result['step']}, "
          f"loss {train_result['loss']:.3f}")
    print("edge inference:", run.context["inference"]["details"]["results"][0])
    print("deployed bytes:", run.context["deployed"]["details"]["bytes"])
    print("losses:", [round(h["loss"], 3) for h in fabric.history])
    assert os.path.isdir(os.path.join(edge.root, "model"))
    print("ML training flow complete.")


if __name__ == "__main__":
    main()
