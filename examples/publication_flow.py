"""Data-publication flow with authorization delegation (paper §2.1.3, MDF).

All eight steps of the Materials Data Facility publication process:
allocate storage, transfer user data, request submitter metadata, automated
metadata extraction, curator approval, DOI minting, search indexing, final
access permissions.

Authorization is the point of this example (paper §4.2.1/§5.1): the flow
runs as the *submitter*, but the DOI-minting and permission steps run under
the ``MDFAdmin`` RunAs role — the service identity's tokens, captured when
the run starts.  Full OAuth-style plumbing is active: flow scope with
dependent AP scopes, consents, delegated token wallets.

    PYTHONPATH=src python examples/publication_flow.py
"""

import os
import tempfile

from repro.core import AuthService, Caller, FlowsService, VirtualClock
from repro.core.actions import ActionRegistry
from repro.core.engine import PollingPolicy
from repro.core.providers import (
    ComputeProvider,
    DOIProvider,
    SearchProvider,
    TransferProvider,
    UserSelectionProvider,
)
from repro.core.providers.user_selection import AutoRespond


def main():
    clock = VirtualClock()
    auth = AuthService()
    workdir = tempfile.mkdtemp(prefix="mdf-")

    registry = ActionRegistry()
    transfer = TransferProvider(clock=clock, auth=auth, workspace=workdir)
    user_src = transfer.create_endpoint("user-laptop")
    transfer.create_endpoint("mdf-storage")
    doi = DOIProvider(clock=clock, auth=auth, namespace="10.18126")
    search = SearchProvider(clock=clock, auth=auth)
    selection = UserSelectionProvider(
        clock=clock, auth=auth,
        auto_respond=AutoRespond(delay_s=3600.0, choice="approve"),
    )  # the curator takes an hour
    compute = ComputeProvider(clock=clock, auth=auth)
    for p in (transfer, doi, search, selection, compute):
        registry.register(p)

    eid = compute.register_endpoint("mdf-extractors")
    f_extract = compute.register_function(
        lambda path: {"format": "vasp", "files": 1, "elements": ["Si", "O"]},
        name="extract_metadata",
        modeled_duration=lambda kw: 45.0,
    )

    flows = FlowsService(registry, clock=clock, auth=auth,
                         polling=PollingPolicy(use_callbacks=True))

    definition = {
        "Comment": "MDF publication (paper §2.1.3 steps 1-8)",
        "StartAt": "AllocateStorage",
        "States": {
            # 1. allocate storage (system credentials: RunAs MDFAdmin)
            "AllocateStorage": {
                "Type": "Action", "ActionUrl": "ap://transfer",
                "RunAs": "MDFAdmin",
                "Parameters": {"operation": "mkdir", "endpoint": "mdf-storage",
                                "path.$": "$.dataset_id"},
                "ResultPath": "$.alloc", "Next": "UploadData"},
            # 2. transfer data (the submitter's credentials)
            "UploadData": {
                "Type": "Action", "ActionUrl": "ap://transfer",
                "Parameters": {
                    "operation": "transfer", "source_endpoint": "user-laptop",
                    "destination_endpoint": "mdf-storage",
                    "source_path.$": "$.source_path",
                    "destination_path.$": "$.dest_path"},
                "ResultPath": "$.upload", "Next": "RequestMetadata"},
            # 3. submitter provides metadata via a web form
            "RequestMetadata": {
                "Type": "Action", "ActionUrl": "ap://user_selection",
                "Parameters": {
                    "prompt": "Confirm dataset title",
                    "options": ["approve", "edit"],
                    "respondents.$": "$.submitter"},
                "ResultPath": "$.meta_form", "Next": "ExtractMetadata"},
            # 4. automated metadata extraction
            "ExtractMetadata": {
                "Type": "Action", "ActionUrl": "ap://compute",
                "Parameters": {"endpoint_id": eid, "function_id": f_extract,
                                "kwargs": {"path.$": "$.dataset_id"}},
                "ResultPath": "$.extracted", "Next": "CuratorReview"},
            # 5. curator approval (may reject -> Fail)
            "CuratorReview": {
                "Type": "Action", "ActionUrl": "ap://user_selection",
                "Parameters": {
                    "prompt": "Approve dataset for publication?",
                    "options": ["approve", "reject"]},
                "ResultPath": "$.review", "Next": "CheckApproval"},
            "CheckApproval": {
                "Type": "Choice",
                "Choices": [{"Variable": "$.review.details.selection",
                              "StringEquals": "approve", "Next": "MintDOI"}],
                "Default": "Rejected"},
            "Rejected": {"Type": "Fail", "Error": "CurationRejected",
                          "Cause": "curator returned dataset to submitter"},
            # 6. DOI (system-owned namespace: RunAs MDFAdmin)
            "MintDOI": {
                "Type": "Action", "ActionUrl": "ap://doi",
                "RunAs": "MDFAdmin",
                "Parameters": {
                    "url.$": "$.landing_page",
                    "metadata.$": "$.extracted.details.results[0]"},
                "ResultPath": "$.doi", "Next": "IndexMetadata"},
            # 7. index in search
            "IndexMetadata": {
                "Type": "Action", "ActionUrl": "ap://search",
                "Parameters": {
                    "operation": "ingest", "index": "mdf",
                    "subject.$": "$.doi.details.doi",
                    "entry.$": "$.extracted.details.results[0]"},
                "ResultPath": "$.indexed", "Next": "SetPermissions"},
            # 8. final access permissions (system credentials)
            "SetPermissions": {
                "Type": "Action", "ActionUrl": "ap://transfer",
                "RunAs": "MDFAdmin",
                "Parameters": {
                    "operation": "set_permissions", "endpoint": "mdf-storage",
                    "path.$": "$.dataset_id",
                    "principals": ["public"]},
                "ResultPath": "$.perms", "End": True},
        },
    }
    record = flows.publish_flow(
        definition,
        input_schema={
            "type": "object",
            "properties": {
                "dataset_id": {"type": "string"},
                "source_path": {"type": "string"},
                "landing_page": {"type": "string"},
                "submitter": {"type": "array"},
            },
            "required": ["dataset_id", "source_path", "landing_page"],
        },
        title="MDF publication",
        owner="mdf-service",
        starters=["all_authenticated_users"],
    )

    # identities + the OAuth dance: both the submitter and the admin role
    # consent to the flow scope (covering its dependent AP scopes)
    auth.create_identity("alice")
    auth.create_identity("mdf-admin")
    auth.grant_consent("alice", record.scope)
    auth.grant_consent("mdf-admin", record.scope)
    alice = Caller(identity=auth.get_identity("alice"),
                   tokens={record.scope: auth.issue_token("alice", record.scope)})
    admin = Caller(identity=auth.get_identity("mdf-admin"),
                   tokens={record.scope: auth.issue_token("mdf-admin",
                                                          record.scope)})

    # the dataset on alice's laptop
    with open(os.path.join(user_src.root, "dft_results.json"), "w") as fh:
        fh.write('{"energy": -132.7}')

    run = flows.run_flow(
        record.flow_id,
        {"dataset_id": "si-o2-dft", "source_path": "dft_results.json",
         "dest_path": "si-o2-dft/dft_results.json",
         "landing_page": "https://mdf.example/si-o2-dft",
         "submitter": ["auto"]},
        caller=alice,
        run_as={"MDFAdmin": admin},
        label="alice-publication",
    )
    flows.engine.run_to_completion(run.run_id)
    print(f"run: {run.status} at virtual t={run.completion_time/3600:.2f} h")
    assert run.status == "SUCCEEDED", run.error
    minted = run.context["doi"]["details"]["doi"]
    print("DOI:", minted, "->", doi.resolve(minted)["url"])
    print("indexed:", list(search.entries("mdf")))
    print("storage now public:",
          transfer.endpoint("mdf-storage").writers == set())
    # provenance: who did what (Fig 2-style events view)
    for e in run.events:
        if e["code"] == "ActionStarted":
            print(f"  t={e['time']:8.1f}  {e['details']['state']:<16} "
                  f"via {e['details']['provider']}")
    print("Publication flow complete.")


if __name__ == "__main__":
    main()
